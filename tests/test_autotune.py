"""Measured-cost subsystem: CostDB, MeasuredCostModel, provider plumbing."""
import math

import pytest

from repro.autotune import (CostDB, CostDBSchemaError, CostDBVersionError,
                            MeasuredCostModel, Record, SCHEMA_VERSION,
                            load_tuned_defaults, run_sweep)
from repro.autotune.bench import estimate_time
from repro.autotune.space import SPACES, ShapeBucket
from repro.core.cluster import PROFILES, DeviceProfile, paper_heterogeneous
from repro.core.cost_model import (ANALYTIC, AnalyticCostModel,
                                   DECODE_ENGINE_EFF, HBM_EFF, PREFILL_MFU,
                                   TRAIN_MFU, _EFF_TABLES, _mfu,
                                   LengthDistribution, ReplicaConfig,
                                   replica_throughput)
from repro.core.model_spec import PAPER_MODELS
from repro.core.scheduler import SchedulerConfig, schedule
from repro.kernels import tuning


def _rec(size=4096, time_s=1e-3, mode="interpret", config=None, **over):
    kw = dict(shape={"B": 1, "S": size, "H": 8, "D": 128}, size=size,
              best_config=config or {"block_q": 128, "block_k": 128},
              time_s=time_s, flops=4e10, useful_flops=3.5e10, bytes=3e8,
              mode=mode, configs_tried=8)
    kw.update(over)
    return Record(**kw)


# ------------------------------------------------------------------- CostDB
def test_costdb_roundtrip(tmp_path):
    db = CostDB()
    db.put("TPUv5e", "flash_attention", "b1_s4096", _rec())
    db.put("TPUv5p", "decode_attention", "b32_c8192",
           _rec(config={"block_c": 512}))
    p = tmp_path / "db.json"
    db.save(p)
    back = CostDB.load(p)
    assert back.to_json() == db.to_json()
    assert back.lookup("TPUv5e", "flash_attention", "b1_s4096") == \
        db.lookup("TPUv5e", "flash_attention", "b1_s4096")


def test_costdb_merge_better_record_wins():
    a = CostDB()
    a.put("TPUv5e", "flash_attention", "b", _rec(time_s=2e-3))
    b = CostDB()
    b.put("TPUv5e", "flash_attention", "b", _rec(time_s=1e-3))
    b.put("TPUv5p", "flash_attention", "b", _rec(time_s=9e-3))
    a.merge(b)
    assert a.lookup("TPUv5e", "flash_attention", "b").time_s == 1e-3
    assert a.lookup("TPUv5p", "flash_attention", "b").time_s == 9e-3
    # a real device measurement beats a faster interpreter estimate
    c = CostDB()
    c.put("TPUv5e", "flash_attention", "b", _rec(time_s=5e-3, mode="device"))
    a.merge(c)
    assert a.lookup("TPUv5e", "flash_attention", "b").mode == "device"
    d = CostDB()
    d.put("TPUv5e", "flash_attention", "b", _rec(time_s=1e-4))
    a.merge(d)   # interpret estimate never displaces a device measurement
    assert a.lookup("TPUv5e", "flash_attention", "b").mode == "device"


def test_costdb_version_mismatch_raises(tmp_path):
    db = CostDB()
    db.put("TPUv5e", "flash_attention", "b", _rec())
    payload = db.to_json()
    payload["schema_version"] = SCHEMA_VERSION + 1
    import json
    p = tmp_path / "future.json"
    p.write_text(json.dumps(payload))
    with pytest.raises(CostDBVersionError):
        CostDB.load(p)
    other = CostDB(schema_version=SCHEMA_VERSION + 1)
    with pytest.raises(CostDBVersionError):
        CostDB().merge(other)


def test_costdb_schema_validation():
    with pytest.raises(CostDBSchemaError):
        CostDB().put("TPUv5e", "flash_attention", "b", _rec(time_s=-1.0))
    with pytest.raises(CostDBSchemaError):
        CostDB().put("TPUv5e", "flash_attention", "b", _rec(mode="guess"))
    with pytest.raises(CostDBSchemaError):
        CostDB.from_json({"entries": {}})
    with pytest.raises(CostDBSchemaError):
        CostDB.from_json({"schema_version": SCHEMA_VERSION,
                          "entries": {"TPUv5e": {"not_a_kernel": {}}}})
    # device types must resolve against core.cluster.PROFILES — a foreign
    # key would otherwise KeyError deep inside the scheduler/fig8
    with pytest.raises(CostDBSchemaError, match="TPUv4"):
        CostDB().put("TPUv4", "flash_attention", "b", _rec())


def test_interpolation_monotone():
    db = CostDB()
    db.put("TPUv5e", "flash_attention", "s1k", _rec(size=1024, time_s=1e-3))
    db.put("TPUv5e", "flash_attention", "s4k", _rec(size=4096, time_s=9e-3))
    db.put("TPUv5e", "flash_attention", "s16k",
           _rec(size=16384, time_s=1.2e-1))
    sizes = [512, 1024, 1500, 2048, 4096, 6000, 10000, 16384, 30000]
    times = [db.interpolated_time("TPUv5e", "flash_attention", s)
             for s in sizes]
    assert all(t is not None and t > 0 for t in times)
    for lo, hi in zip(times[:-1], times[1:]):
        assert hi > lo, (times, "interpolated time must grow with size")
    # exact at the buckets
    assert math.isclose(times[sizes.index(4096)], 9e-3)
    # no coverage → None (caller falls back to analytic)
    assert db.interpolated_time("TPUv5e", "decode_attention", 4096) is None
    assert db.interpolated_time("H800", "flash_attention", 4096) is None


# -------------------------------------------------------- MeasuredCostModel
def test_empty_db_falls_back_to_analytic():
    m = MeasuredCostModel(CostDB())
    for prof in PROFILES.values():
        assert m.factors(prof) == ANALYTIC.factors(prof)


def test_partial_db_falls_back_per_factor_and_type():
    db = CostDB()
    db.put("TPUv5e", "flash_attention", "b", _rec())
    m = MeasuredCostModel(db)
    v5e, v5p = PROFILES["TPUv5e"], PROFILES["TPUv5p"]
    # covered: flash-derived factors move
    assert m.prefill_mfu(v5e) != ANALYTIC.prefill_mfu(v5e)
    assert m.train_mfu(v5e) != ANALYTIC.train_mfu(v5e)
    # no decode records → HBM factors stay analytic even for the covered type
    assert m.hbm_eff(v5e) == ANALYTIC.hbm_eff(v5e)
    # uncovered type → fully analytic
    assert m.factors(v5p) == ANALYTIC.factors(v5p)


def test_measured_efficiency_derivation():
    prof = PROFILES["TPUv5e"]
    db = CostDB()
    rec = _rec(time_s=1e-3)
    db.put("TPUv5e", "flash_attention", "b", rec)
    m = MeasuredCostModel(db)
    want = rec.useful_flops / (rec.time_s * prof.flops)
    assert math.isclose(m.prefill_mfu(prof), want, rel_tol=1e-9)
    ratio = TRAIN_MFU["TPUv5e"] / PREFILL_MFU["TPUv5e"]
    assert math.isclose(m.train_mfu(prof), want * ratio, rel_tol=1e-9)


# -------------------------------------------------------- provider plumbing
P_FAST = LengthDistribution(mean_len=1024, prompt_len=128)
CFG_FAST = SchedulerConfig(tokens_per_step=2 ** 18, stable_iters=3,
                           max_iters=8, adapt_delta=False)


def test_schedule_identical_with_default_provider():
    """Guard against plan drift: no provider, explicit analytic provider,
    and an empty-DB measured overlay must all make the same decision and
    price it identically (byte-identical costs)."""
    cluster = paper_heterogeneous(8, 16)
    spec = PAPER_MODELS["1.5B"]
    base = schedule(spec, cluster, P_FAST, CFG_FAST)
    for provider in (AnalyticCostModel(), MeasuredCostModel(CostDB())):
        p = schedule(spec, cluster, P_FAST, CFG_FAST, cost_provider=provider)
        assert p.signature() == base.signature()
        assert p.cost_train == base.cost_train
        assert p.cost_infer == base.cost_infer
        assert p.objective == base.objective


def test_measured_provider_changes_pricing():
    cluster = paper_heterogeneous(8, 16)
    spec = PAPER_MODELS["1.5B"]
    db = CostDB()
    # pretend H20 prefill measures far below the analytic guess
    db.put("H20", "flash_attention", "b",
           _rec(time_s=5e-3, useful_flops=3.5e10))
    base = schedule(spec, cluster, P_FAST, CFG_FAST)
    p = schedule(spec, cluster, P_FAST, CFG_FAST,
                 cost_provider=MeasuredCostModel(db))
    assert (p.signature() != base.signature()
            or p.cost_infer != base.cost_infer
            or p.cost_train != base.cost_train)


def test_replica_throughput_uses_provider():
    spec = PAPER_MODELS["1.5B"]
    class Half(AnalyticCostModel):
        def decode_engine_eff(self, profile):
            return super().decode_engine_eff(profile) / 2.0
    rc = replica_throughput(spec, ReplicaConfig("H20", (4,)), P_FAST)
    rc_half = replica_throughput(spec, ReplicaConfig("H20", (4,)), P_FAST,
                                 cost_provider=Half())
    assert math.isclose(rc_half.tokens_per_sec, rc.tokens_per_sec / 2.0,
                        rel_tol=1e-9)


# ------------------------------------------------------------ strict tables
def test_mfu_unknown_profile_raises():
    ghost = DeviceProfile(name="GhostTPU", flops=1e12, hbm_bw=1e11,
                          hbm_cap=8 * 1024 ** 3, intra_bw=1e10, inter_bw=1e9)
    with pytest.raises(KeyError, match="GhostTPU"):
        _mfu(TRAIN_MFU, ghost)
    with pytest.raises(KeyError, match="MeasuredCostModel"):
        ANALYTIC.decode_engine_eff(ghost)


def test_profile_coverage():
    for tname, table in _EFF_TABLES.items():
        for p in PROFILES:
            assert p in table, (tname, p)


# ------------------------------------------------------------ sweep + tuning
# ~7s: full interpreter-mode sweep; CI runs the same sweep directly
# via `python -m repro.autotune sweep --tiny` in its own step.
@pytest.mark.slow
def test_tiny_sweep_smoke():
    """Interpreter-mode sweep of one shape per kernel: every requested
    (device × kernel) gets a record, the schema round-trips, and the
    derived factors differ from the analytic tables."""
    db = run_sweep(tiny=True, log=lambda s: None)
    assert CostDB.from_json(db.to_json()).to_json() == db.to_json()
    from repro.autotune.costdb import KERNELS
    assert "paged_attention" in KERNELS        # serving coverage is gated
    for dt in ("TPUv5e", "TPUv5p"):
        for kernel in KERNELS:
            recs = db.records(dt, kernel)
            assert recs, (dt, kernel)
            for r in recs.values():
                assert r.mode == "interpret"      # CI runs on CPU
                assert r.configs_tried <= 8       # the --tiny contract
    m = MeasuredCostModel(db)
    moved = any(
        abs(getattr(m, key)(PROFILES[dt]) - getattr(ANALYTIC, key)(
            PROFILES[dt])) / getattr(ANALYTIC, key)(PROFILES[dt]) > 0.05
        for dt in ("TPUv5e", "TPUv5p")
        for key in ("train_mfu", "prefill_mfu", "hbm_eff"))
    assert moved, "sweep-derived factors identical to the analytic tables"


def test_estimator_prefers_feasible_blocks():
    space = SPACES["flash_attention"]
    shape = ShapeBucket.make("s", B=1, S=4096, H=8, D=128)
    prof = PROFILES["TPUv5e"]
    # padding waste: a block far larger than the sequence must price worse
    small = estimate_time(space, ShapeBucket.make("s", B=1, S=256, H=8,
                                                  D=128),
                          {"block_q": 128, "block_k": 128}, prof)
    huge = estimate_time(space, ShapeBucket.make("s", B=1, S=256, H=8,
                                                 D=128),
                         {"block_q": 512, "block_k": 512}, prof)
    assert small < huge
    assert space.feasible(shape, {"block_q": 128, "block_k": 128}, "TPUv5e")


def test_tuned_defaults_flow_into_ops():
    db = CostDB()
    db.put("TPUv5e", "flash_attention", "b",
           _rec(config={"block_q": 256, "block_k": 128}))
    db.put("TPUv5e", "ssm_scan", "b", _rec(config={"chunk": 128}))
    tuning.clear_tuned()
    try:
        n = load_tuned_defaults(db)
        assert n == 2
        with tuning.override_device_type("TPUv5e"):
            assert tuning.tuned_config("flash_attention") == {
                "block_q": 256, "block_k": 128}
            assert tuning.resolve("ssm_scan", "chunk", None) == 128
            # explicit arg still wins over the tuned table
            assert tuning.resolve("ssm_scan", "chunk", 32) == 32
        # off-device (CPU/unknown): historical defaults
        with tuning.override_device_type(None):
            assert tuning.tuned_config("flash_attention") == {
                "block_q": 128, "block_k": 128}
    finally:
        tuning.clear_tuned()


def test_register_tuned_rejects_unknown_knobs():
    with pytest.raises(KeyError):
        tuning.register_tuned("TPUv5e", "flash_attention", {"block_z": 64})
    with pytest.raises(KeyError):
        tuning.register_tuned("TPUv5e", "warp_drive", {"block_q": 64})
