"""Gradient compression (subprocess, multi-device) + roofline parsing."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

COMPRESS_TEST = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp
    from repro.parallel.compression import (init_residual,
                                            make_compressed_allreduce)
    mesh = jax.make_mesh((4,), ("data",))
    f = make_compressed_allreduce(mesh, "data")
    key = jax.random.PRNGKey(0)
    grads = {"w": jax.random.normal(key, (32, 32))}
    res = init_residual(grads)
    mean, res2 = f(grads, res)
    # every shard holds identical grads (replicated) → mean == grads
    err = float(jnp.max(jnp.abs(mean["w"] - grads["w"])))
    scale = float(jnp.max(jnp.abs(grads["w"]))) / 127.0
    # residual carries the quantization error exactly
    rec = float(jnp.max(jnp.abs(res2["w"] + mean["w"] - grads["w"])))
    print(json.dumps(dict(err=err, bound=scale, rec=rec)))
""")


def test_compressed_allreduce_bounded_error():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", COMPRESS_TEST],
                         capture_output=True, text=True, env=env,
                         timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["err"] <= res["bound"] * 0.75 + 1e-6
    assert res["rec"] <= res["bound"] * 0.75 + 1e-6


# ------------------------------------------------------- roofline parsing
def test_parse_collectives_counts_and_wire_bytes():
    from repro.launch.roofline import parse_collectives
    hlo = """
      %ar = f32[128,256]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}
      %ag.1 = bf16[64]{0} all-gather(%y), replica_groups={{0,1}}
      %rs = f32[32]{0} reduce-scatter(%z), replica_groups={{0,1,2,3}}
      %done = f32[8]{0} all-reduce-done(%h)
      %cp = (s32[4]{0}, s32[4]{0}) collective-permute(%a, %b)
    """
    st = parse_collectives(hlo)
    assert st.counts["all-reduce"] == 1          # -done not double counted
    assert st.counts["all-gather"] == 1
    assert st.counts["reduce-scatter"] == 1
    assert st.counts["collective-permute"] == 1
    ar_bytes = 128 * 256 * 4
    assert st.result_bytes["all-reduce"] == ar_bytes
    assert st.wire_bytes["all-reduce"] == pytest.approx(
        2 * 3 / 4 * ar_bytes)
    assert st.wire_bytes["reduce-scatter"] == pytest.approx(3 * 32 * 4)


def test_loop_flop_correction_families():
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES
    from repro.launch.roofline import loop_flop_correction
    # full attention, 4k train: kv chunks = 4 → correction > 0
    c = loop_flop_correction(get_config("yi-34b"), SHAPES["train_4k"])
    assert c > 0
    # decode lowers UNCHUNKED (single-token fast path) → no correction
    assert loop_flop_correction(get_config("yi-34b"),
                                SHAPES["decode_32k"]) == 0.0
    # xlstm decode: single recurrent step, no loop → zero
    assert loop_flop_correction(get_config("xlstm-1.3b"),
                                SHAPES["decode_32k"]) == 0.0


def test_model_flops_for_cell_scaling():
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES
    from repro.launch.roofline import model_flops_for_cell
    cfg = get_config("qwen2.5-3b")
    tr = model_flops_for_cell(cfg, SHAPES["train_4k"])
    pf = model_flops_for_cell(cfg, SHAPES["prefill_32k"])
    de = model_flops_for_cell(cfg, SHAPES["decode_32k"])
    assert tr > pf > de
    # train = 6·N·D with D = 256·4096
    n_act = cfg.spec.params(active_only=True)
    assert tr == pytest.approx(6 * n_act * 256 * 4096)
