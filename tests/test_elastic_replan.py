"""Elastic replanning: scheduler determinism, warm-started reschedule,
conservation invariants, and the η staleness bound across plan swaps."""
import pytest

from repro.core.cluster import Cluster, paper_heterogeneous
from repro.core.cost_model import LengthDistribution
from repro.core.model_spec import PAPER_MODELS
from repro.core.scheduler import SchedulerConfig, reschedule, schedule
from repro.core.staleness import StalenessConfig, StalenessController
from repro.rl.buffer import RolloutBuffer
from repro.sim import (AsyncRLSimulator, ElasticConfig, ElasticReplanner,
                       FailureInjection, SimConfig, StragglerInjection)

SPEC = PAPER_MODELS["1.5B"]
P = LengthDistribution(mean_len=1024, prompt_len=128)
SCHED_CFG = SchedulerConfig(tokens_per_step=2 ** 18, stable_iters=3,
                            max_iters=12, adapt_delta=False)
SIM = dict(n_steps=12, rollouts_per_step=32, eta=4, reward_cost_s=0.1)


@pytest.fixture(scope="module")
def cluster():
    return paper_heterogeneous(16, 16)     # 2 H800 + 2 H20 nodes


@pytest.fixture(scope="module")
def plan(cluster):
    return schedule(SPEC, cluster, P, SCHED_CFG)


def _fast_replica_failures(plan, t_fail=8.0):
    """Kill every H800 rollout replica (the fast pool) at t_fail."""
    idx, fails = 0, []
    for a in plan.rollout_plan.assignments:
        for _ in range(a.count):
            if a.config.profile_name == "H800":
                fails.append(FailureInjection(idx, t_fail=t_fail))
            idx += 1
    assert fails, "plan has no fast rollout replicas to kill"
    return fails


def _elastic(plan, cluster, churn, latency=4.0):
    rp = ElasticReplanner(SPEC, cluster, P, SCHED_CFG,
                          ElasticConfig(replan_latency_s=latency,
                                        straggler_threshold=0.5))
    return AsyncRLSimulator(plan, P, SimConfig(
        **SIM, **churn, replanner=rp, check_invariants=True)).run()


# ------------------------------------------------------------- determinism
def test_schedule_deterministic(cluster):
    """Same Cluster + SchedulerConfig ⇒ identical ScheduledPlan decision
    (guards the reschedule warm-start against nondeterminism)."""
    a = schedule(SPEC, cluster, P, SCHED_CFG)
    b = schedule(SPEC, cluster, P, SCHED_CFG)
    assert a.signature() == b.signature()
    assert a.delta == b.delta and a.gamma == b.gamma


def test_reschedule_deterministic_and_provenanced(cluster, plan):
    survivors = Cluster(devices=cluster.devices[:24],
                        cross_type_bw=cluster.cross_type_bw)
    a = reschedule(SPEC, survivors, plan, P, SCHED_CFG, reason="failure")
    b = reschedule(SPEC, survivors, plan, P, SCHED_CFG, reason="failure")
    assert a.signature() == b.signature()
    # provenance chain: epoch bumped, parent recorded, δ pinned
    assert a.plan_epoch == plan.plan_epoch + 1
    assert a.parent_epoch == plan.plan_epoch
    assert a.provenance == "replan:failure"
    assert a.delta == plan.delta
    # the reduced plan only uses surviving devices
    used = set(a.train_devices) | set(a.infer_devices)
    assert used <= {d.index for d in survivors.devices}


def test_simulator_deterministic_given_seed(plan):
    r1 = AsyncRLSimulator(plan, P, SimConfig(**SIM, seed=7)).run()
    r2 = AsyncRLSimulator(plan, P, SimConfig(**SIM, seed=7)).run()
    assert r1.wall_time_s == r2.wall_time_s
    assert r1.tokens_consumed == r2.tokens_consumed
    assert r1.rollouts_launched == r2.rollouts_launched


# ------------------------------------------------------------ conservation
def test_conservation_ledger_no_churn(plan):
    res = AsyncRLSimulator(plan, P, SimConfig(
        **SIM, check_invariants=True)).run()
    assert res.steps == SIM["n_steps"]
    # launched == trained + dropped + buffered + still generating
    assert res.rollouts_launched == (res.rollouts_trained + res.dropped +
                                     res.rollouts_in_buffer +
                                     res.rollouts_generating)
    assert res.rollouts_trained == SIM["n_steps"] * SIM["rollouts_per_step"]


def test_conservation_ledger_across_swap(plan, cluster):
    res = _elastic(plan, cluster,
                   dict(failures=_fast_replica_failures(plan)))
    assert res.steps == SIM["n_steps"]
    assert len(res.swaps) >= 1           # the replan actually happened
    assert res.rollouts_launched == (res.rollouts_trained + res.dropped +
                                     res.rollouts_in_buffer +
                                     res.rollouts_generating)


# --------------------------------------------------------- η across swaps
def test_staleness_bound_holds_across_plan_swap(plan, cluster):
    """Acceptance: the η bound holds on both sides of ≥1 mid-run swap."""
    eta = SIM["eta"]
    res = _elastic(plan, cluster,
                   dict(failures=_fast_replica_failures(plan)))
    assert len(res.swaps) >= 1
    assert res.max_staleness <= eta
    assert res.mean_staleness <= eta
    for s in res.swaps:
        assert s.max_staleness_before <= eta
        assert s.max_staleness_after <= eta
        assert s.mean_staleness_before <= eta
        assert s.mean_staleness_after <= eta
        assert s.t_commit >= s.t_request
        assert s.n_replicas_after > 0


def test_sustained_straggler_triggers_replan(plan, cluster):
    idx = len(AsyncRLSimulator(plan, P).replicas) - 1
    res = _elastic(plan, cluster, dict(
        stragglers=[StragglerInjection(idx, factor=0.1, t_start=5.0)]))
    assert any(tr.reason == "straggler" for tr in res.replan_triggers)
    assert len(res.swaps) >= 1
    assert res.max_staleness <= SIM["eta"]


# ----------------------------------------------------- replanning pays off
def test_elastic_beats_static_under_failures(plan, cluster):
    churn = dict(failures=_fast_replica_failures(plan))
    static = AsyncRLSimulator(plan, P, SimConfig(
        **SIM, **churn, check_invariants=True)).run()
    el = _elastic(plan, cluster, churn)
    assert el.throughput_tps >= static.throughput_tps
    # throughput attribution covers the whole run, split at the swap
    assert [e.epoch for e in el.plan_epochs] == \
        sorted(e.epoch for e in el.plan_epochs)
    assert sum(e.steps for e in el.plan_epochs) == el.steps


# ------------------------------------------------ epoch accounting plumbing
def test_replica_device_mapping_disjoint(plan, cluster):
    rp = ElasticReplanner(SPEC, cluster, P, SCHED_CFG)
    rmap = rp.replica_devices(plan)
    assert len(rmap) == len(AsyncRLSimulator(plan, P).replicas)
    seen = set()
    infer = set(plan.infer_devices)
    for devs in rmap:
        assert devs, "replica mapped to no devices"
        for d in devs:
            assert d.index in infer
            assert d.index not in seen    # no device serves two replicas
            seen.add(d.index)


def test_controller_swap_preserves_version_stream():
    ctl = StalenessController(StalenessConfig(eta=2, rollouts_per_step=4))
    ctl.launch(4)
    v = ctl.version
    epoch = ctl.record_plan_swap()
    assert epoch == 1
    assert ctl.version == v               # swap never touches versions
    assert ctl.in_flight == 4             # in-flight work survives the swap
    ctl.consume([v] * 4)                  # still admissible afterwards
    assert ctl.swap_history() == [(1, v)]


def test_buffer_swap_keeps_rollouts_admissible():
    buf = RolloutBuffer(StalenessConfig(eta=1, rollouts_per_step=2))
    from repro.rl.buffer import Rollout
    buf.launch(2)
    for g in range(2):
        buf.push(Rollout([1], [2], None, version=0, group_id=g))
    assert buf.on_plan_swap() == 1
    assert buf.plan_epoch == 1
    buf.launch(1)                         # post-swap rollout gets the new epoch
    buf.push(Rollout([1], [2], None, version=0, group_id=2))
    batch = buf.pop_batch(2)              # η admission unaffected by swap
    assert len(batch) == 2
    assert [r.plan_epoch for r in batch] == [0, 0]   # stamped pre-swap
    assert buf.pop_batch(1)[0].plan_epoch == 1       # stamped post-swap
    assert buf.stats()["plan_swaps"] == 1
