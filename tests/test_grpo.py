"""GRPO objective tests: loss math vs naive impl, advantage properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # minimal envs: seeded-sampling shim
    from _prop import given, settings, st

from repro.rl.grpo import (grpo_loss, group_advantages,
                           token_logp_from_logits)


@given(st.lists(st.floats(0, 1), min_size=4, max_size=32),
       st.integers(2, 4))
@settings(max_examples=50, deadline=None)
def test_group_advantages_zero_mean(rewards, gsize):
    rewards = np.array(rewards[: (len(rewards) // gsize) * gsize])
    if len(rewards) == 0:
        return
    groups = np.repeat(np.arange(len(rewards) // gsize), gsize)
    adv = group_advantages(rewards, groups)
    for g in np.unique(groups):
        assert abs(adv[groups == g].mean()) < 1e-5


def test_token_logp_matches_log_softmax():
    rng = jax.random.PRNGKey(0)
    logits = jax.random.normal(rng, (2, 5, 11))
    tgt = jax.random.randint(rng, (2, 5), 0, 11)
    lp = token_logp_from_logits(logits, tgt)
    full = jax.nn.log_softmax(logits, axis=-1)
    ref = jnp.take_along_axis(full, tgt[..., None], axis=-1)[..., 0]
    np.testing.assert_allclose(np.asarray(lp), np.asarray(ref), atol=1e-5)


def _naive_grpo(logits, tokens, blogp, adv, mask, eps):
    lp = np.asarray(jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32),
                                       -1))
    tgt = np.asarray(tokens[:, 1:])
    m = np.asarray(mask[:, 1:])
    taken = np.take_along_axis(lp, tgt[..., None], -1)[..., 0]
    ratio = np.exp(taken - np.asarray(blogp[:, 1:]))
    a = np.asarray(adv)[:, None]
    unc = ratio * a
    cl = np.clip(ratio, 1 - eps, 1 + eps) * a
    pg = -np.minimum(unc, cl)
    return (pg * m).sum() / max(m.sum(), 1.0)


def test_grpo_loss_matches_naive():
    rng = jax.random.PRNGKey(3)
    B, S, V = 4, 12, 17
    logits = jax.random.normal(rng, (B, S, V))
    tokens = jax.random.randint(rng, (B, S), 0, V)
    blogp = -1.5 + 0.1 * jax.random.normal(rng, (B, S))
    adv = jnp.array([1.0, -0.5, 0.2, 0.0])
    mask = jnp.ones((B, S))
    loss, metrics = grpo_loss(logits, tokens, blogp, adv, mask,
                              clip_eps=0.2)
    ref = _naive_grpo(logits, tokens, blogp, adv, mask, 0.2)
    assert float(loss) == pytest.approx(float(ref), rel=1e-4)
    assert 0.0 <= float(metrics["clip_frac"]) <= 1.0


def test_grpo_onpolicy_gradient_direction():
    """On-policy (ratio=1): positive advantage ⇒ loss decreases when the
    chosen token's logit increases."""
    V = 7
    logits = jnp.zeros((1, 3, V))
    tokens = jnp.array([[1, 2, 3]])
    mask = jnp.ones((1, 3))
    adv = jnp.array([1.0])
    blogp = token_logp_from_logits(logits[:, :-1], tokens[:, 1:])
    blogp = jnp.pad(blogp, ((0, 0), (1, 0)))

    def f(lg):
        return grpo_loss(lg, tokens, blogp, adv, mask)[0]

    g = jax.grad(f)(logits)
    # gradient on the taken token's logit should be negative (push up)
    assert float(g[0, 0, 2]) < 0
    assert float(g[0, 1, 3]) < 0


def test_decoupled_objective_importance_weight():
    """Stale behavior policy enters only through the stop-grad weight."""
    rng = jax.random.PRNGKey(5)
    B, S, V = 2, 6, 9
    logits = jax.random.normal(rng, (B, S, V))
    tokens = jax.random.randint(rng, (B, S), 0, V)
    prox = token_logp_from_logits(logits[:, :-1], tokens[:, 1:])
    prox = jnp.pad(prox, ((0, 0), (1, 0)))
    stale = prox - 0.5          # behavior logp offset
    adv = jnp.array([1.0, -1.0])
    mask = jnp.ones((B, S))
    l_dec, _ = grpo_loss(logits, tokens, stale, adv, mask,
                         prox_logp=prox)
    assert bool(jnp.isfinite(l_dec))
