"""Multi-tenant control plane: lifecycle state machine, priced admission,
typed pool infeasibility (no ``InfeasibleScheduleError`` ever escapes
``schedule_pool``/``replan_pool``), online arrival/departure through the
simulator, straggler/transient-downtime injection in the multi-job
machine, and full state reclaim on departure (ISSUE 6)."""
import pytest

from repro.core.cluster import paper_heterogeneous
from repro.core.cost_model import LengthDistribution
from repro.core.jobs import (AdmissionConfig, ControlPlane,
                             EwmaThroughputTrend, InvalidTransitionError,
                             JobRecord, JobState, TrendConfig)
from repro.core.model_spec import PAPER_MODELS
from repro.core.pool import (JobSpec, PoolConfig, PoolInfeasibleError,
                             replan_pool, schedule_pool)
from repro.core.scheduler import InfeasibleScheduleError, SchedulerConfig
from repro.core.staleness import (PoolStalenessRegistry, StalenessConfig)
from repro.rl.buffer import JobBuffers, Rollout
from repro.sim import (ElasticConfig, JobArrival, JobFailure, JobStraggler,
                       MultiJobSimulator, MultiSimConfig, PoolReplanner)

P = LengthDistribution(mean_len=1024, prompt_len=128)


def _cfg(eta: int = 4) -> SchedulerConfig:
    return SchedulerConfig(tokens_per_step=2 ** 18, stable_iters=3,
                           max_iters=12, adapt_delta=False,
                           staleness=StalenessConfig(eta=eta))


def _jobs():
    return [JobSpec("j1.5b", PAPER_MODELS["1.5B"], P, _cfg(eta=4),
                    weight=1.0),
            JobSpec("j7b", PAPER_MODELS["7B"], P, _cfg(eta=2), weight=4.0)]


@pytest.fixture(scope="module")
def cluster():
    return paper_heterogeneous(8, 56)


@pytest.fixture(scope="module")
def pool(cluster):
    return schedule_pool(_jobs(), cluster)


# ------------------------------------------------------------- lifecycle
def test_lifecycle_legal_path():
    rec = JobRecord(JobSpec("a", PAPER_MODELS["1.5B"], P, _cfg()),
                    t_submit=1.0)
    assert rec.state is JobState.PENDING
    rec.to(JobState.ADMITTED, 2.0).to(JobState.RUNNING, 3.0)
    assert rec.admission_latency_s == 2.0
    rec.to(JobState.DRAINING, 9.0).to(JobState.COMPLETED, 10.0)
    assert rec.state.terminal and rec.t_end == 10.0
    assert [s.value for s, _, _ in rec.history] == [
        "PENDING", "ADMITTED", "RUNNING", "DRAINING", "COMPLETED"]


def test_lifecycle_illegal_transitions_raise():
    rec = JobRecord(JobSpec("a", PAPER_MODELS["1.5B"], P, _cfg()),
                    t_submit=0.0)
    with pytest.raises(InvalidTransitionError):
        rec.to(JobState.RUNNING, 1.0)          # must be admitted first
    rec.to(JobState.REJECTED, 1.0, "floor")
    with pytest.raises(InvalidTransitionError):
        rec.to(JobState.ADMITTED, 2.0)         # terminal states are final
    assert rec.admission_latency_s is None     # never started


# ------------------------------------------------------------- admission
def test_admission_rejects_infeasible_with_typed_diagnostic():
    cp = ControlPlane(paper_heterogeneous(0, 8))   # 1 node: unbipartitionable
    dec = cp.submit(JobSpec("big", PAPER_MODELS["14B"], P, _cfg()), t=5.0)
    assert dec.action == "reject" and "infeasible" in dec.reason
    assert cp.records["big"].state is JobState.REJECTED


def test_admission_rejects_on_priced_throughput_floor():
    cp = ControlPlane(paper_heterogeneous(0, 16))
    spec = JobSpec("floor", PAPER_MODELS["14B"], P, _cfg(), min_tput=1e9)
    dec = cp.submit(spec, t=0.0)
    assert dec.action == "reject" and "floor" in dec.reason
    assert 0 < dec.solo_tput < 1e9             # priced, then found wanting
    ok = cp.submit(JobSpec("fine", PAPER_MODELS["1.5B"], P, _cfg(),
                           min_tput=100.0), t=1.0)
    assert ok.action == "queue" and ok.solo_tput > 100.0


def test_admission_queue_bound():
    cp = ControlPlane(paper_heterogeneous(0, 16),
                      cfg=AdmissionConfig(max_queue=1))
    assert cp.submit(JobSpec("q1", PAPER_MODELS["1.5B"], P, _cfg()),
                     t=0.0).action == "queue"
    dec = cp.submit(JobSpec("q2", PAPER_MODELS["1.5B"], P, _cfg()), t=1.0)
    assert dec.action == "reject" and dec.reason == "queue_full"
    assert [r.name for r in cp.queued()] == ["q1"]
    with pytest.raises(ValueError):
        cp.submit(JobSpec("q1", PAPER_MODELS["1.5B"], P, _cfg()), t=2.0)


def test_admission_retry_tick_reprices_queued_jobs():
    cp = ControlPlane(paper_heterogeneous(0, 16),
                      cfg=AdmissionConfig(retry_interval_s=10.0))
    assert cp.submit(JobSpec("waiter", PAPER_MODELS["1.5B"], P, _cfg(),
                             min_tput=100.0), t=0.0).action == "queue"
    assert cp.tick(5.0) == []                  # interval not yet elapsed
    assert cp.records["waiter"].retries == 0
    due = cp.tick(12.0)                        # re-priced, still admissible
    assert due == ["waiter"]
    assert cp.records["waiter"].retries == 1
    assert cp.decisions[-1].action == "retry"
    assert cp.tick(13.0) == []                 # interval restarts at 12.0
    # capacity shrank while queued: the retry pricing now misses the
    # floor and the job is rejected instead of starving in the queue
    assert cp.tick(25.0, cluster=paper_heterogeneous(0, 4)) == []
    assert cp.records["waiter"].state is JobState.REJECTED
    assert cp.records["waiter"].reason.startswith("retry:")


def test_admission_tick_disabled_by_default():
    cp = ControlPlane(paper_heterogeneous(0, 16))
    cp.submit(JobSpec("q", PAPER_MODELS["1.5B"], P, _cfg()), t=0.0)
    assert cp.tick(1e9) == []                  # no interval → never due
    assert cp.records["q"].retries == 0


# ----------------------------------------------------- typed infeasibility
def test_schedule_pool_single_job_infeasibility_is_typed():
    """The degenerate single-job path used to let InfeasibleScheduleError
    escape the pool entry point (satellite bugfix)."""
    with pytest.raises(RuntimeError) as ei:
        schedule_pool([JobSpec("big", PAPER_MODELS["14B"], P, _cfg())],
                      paper_heterogeneous(0, 8))
    assert isinstance(ei.value, PoolInfeasibleError)
    assert not isinstance(ei.value, InfeasibleScheduleError)
    assert ei.value.infeasible["big"].reason == "infeasible"


def test_schedule_pool_partial_mode_sheds_by_priority():
    cl = paper_heterogeneous(8, 8)             # 2 domains < 2 jobs × 2 min
    plan = schedule_pool(_jobs(), cl, PoolConfig(min_domains_per_job=2),
                         allow_partial=True)
    plan.assert_partition(cl)
    # the lighter job sheds first (drop order: tier, then weight)
    assert [j.name for j in plan.jobs] == ["j7b"]
    assert plan.infeasible["j1.5b"].reason == "min_domains"
    with pytest.raises(PoolInfeasibleError):   # strict mode still raises
        schedule_pool(_jobs(), cl, PoolConfig(min_domains_per_job=2))


def test_tier_beats_weight_in_shed_order():
    heavy_low = JobSpec("heavy", PAPER_MODELS["7B"], P, _cfg(eta=2),
                        weight=4.0, tier=1)    # lower priority tier
    light_high = JobSpec("light", PAPER_MODELS["1.5B"], P, _cfg(),
                         weight=1.0, tier=0)
    plan = schedule_pool([heavy_low, light_high], paper_heterogeneous(8, 8),
                         PoolConfig(min_domains_per_job=2),
                         allow_partial=True)
    assert [j.name for j in plan.jobs] == ["light"]
    assert "heavy" in plan.infeasible


# ------------------------------------------------------ departure/arrival
def test_replan_departure_reclaims_slice(pool, cluster):
    new = replan_pool(pool, cluster, reason="departure", departed=["j7b"])
    new.assert_partition(cluster)
    assert [j.name for j in new.jobs] == ["j1.5b"]
    assert set(new.owner.values()) == {"j1.5b"}
    assert "j7b" not in new.plans


def test_replan_arrival_seeded_from_surplus(pool, cluster):
    arr = JobSpec("newbie", PAPER_MODELS["1.5B"], P, _cfg(), weight=1.0)
    new = replan_pool(pool, cluster, reason="arrival", arrivals=[arr],
                      allow_partial=True)
    new.assert_partition(cluster)
    assert new.job_devices("newbie")           # fed by donors' surplus
    assert not new.infeasible
    for j in pool.jobs:                        # carried jobs keep δ pinned
        assert new.plans[j.name].delta == pool.plans[j.name].delta
    with pytest.raises(ValueError):            # name collision is an error
        replan_pool(pool, cluster, arrivals=[_jobs()[0]])


# ------------------------------------------------------------------ trend
def test_ewma_trend_detector():
    tr = EwmaThroughputTrend(TrendConfig(alpha=0.5, min_samples=3,
                                         threshold=0.6))
    assert not any(tr.observe(100.0) for _ in range(5))   # steady: no trigger
    assert not tr.observe(80.0)                # dip, EWMA still above bar
    assert tr.observe(10.0)                    # sustained collapse trips it
    tr.reset()
    assert tr.ewma is None and not tr.observe(10.0)   # new baseline


# ------------------------------------------------- multi-sim fault paths
def test_multi_sim_honors_stragglers(pool, cluster):
    """Satellite bugfix: JobStraggler used to be silently ignored."""
    rp = PoolReplanner(cluster, elastic=ElasticConfig(replan_latency_s=4.0))
    res = MultiJobSimulator(pool, MultiSimConfig(
        n_steps=6,
        stragglers=[JobStraggler("j7b", 0, factor=0.3, t_start=10.0),
                    JobStraggler("j7b", 0, factor=0.3, t_start=20.0)],
        replanner=rp, check_invariants=True)).run()
    assert any(r.reason == "straggler" for r in res.replan_triggers)
    assert res.pool_swaps >= 1
    for r in res.per_job.values():
        assert r.steps == 6


def test_multi_sim_transient_downtime_recovers(pool, cluster):
    """A JobFailure with a downtime is transient: no devices are excluded
    and the run completes on the full fleet."""
    res = MultiJobSimulator(pool, MultiSimConfig(
        n_steps=6,
        failures=[JobFailure("j1.5b", 0, t_fail=10.0, downtime=20.0)],
        replanner=PoolReplanner(cluster),
        check_invariants=True)).run()
    assert not res.excluded                    # transient ≠ permanent
    for r in res.per_job.values():
        assert r.steps == 6


def test_multi_sim_trend_triggers_predictive_replan(pool, cluster):
    """Sustained degradation (every replica slowed, no single failure)
    trips the EWMA detector and replans without a failure event."""
    rp = PoolReplanner(cluster, elastic=ElasticConfig(
        replan_latency_s=4.0, straggler_threshold=0.0))  # no direct trigger
    res = MultiJobSimulator(pool, MultiSimConfig(
        n_steps=16,
        stragglers=[JobStraggler("j1.5b", i, factor=0.005, t_start=20.0)
                    for i in range(64)],
        replanner=rp, trend=TrendConfig(alpha=0.5, min_samples=3,
                                        threshold=0.6),
        check_invariants=True)).run()
    assert any(r.reason == "trend" for r in res.replan_triggers)
    assert res.pool_swaps >= 1
    for r in res.per_job.values():
        assert r.steps == 16


def test_multisim_validates_control_plane_needs_replanner(pool):
    with pytest.raises(ValueError):
        MultiJobSimulator(pool, MultiSimConfig(depart_on_completion=True))
    with pytest.raises(ValueError):
        MultiJobSimulator(pool, MultiSimConfig(
            arrivals=[JobArrival(JobSpec("x", PAPER_MODELS["1.5B"], P,
                                         _cfg()), t_submit=1.0)]))


# ----------------------------------------- online arrival/departure, e2e
def test_multi_sim_online_arrival_and_departure(pool, cluster):
    rp = PoolReplanner(cluster, elastic=ElasticConfig(replan_latency_s=4.0))
    arr = JobSpec("newbie", PAPER_MODELS["1.5B"], P, _cfg(), weight=1.0)
    res = MultiJobSimulator(pool, MultiSimConfig(
        n_steps=8, arrivals=[JobArrival(arr, t_submit=40.0, n_steps=3)],
        depart_on_completion=True, replanner=rp,
        check_invariants=True)).run()
    # admitted mid-run, ran its (overridden) budget, then departed
    assert res.per_job["newbie"].steps == 3
    assert res.records["newbie"].state is JobState.COMPLETED
    lat = res.admission_latencies()["newbie"]
    assert 0 < lat <= 2 * rp.elastic.replan_latency_s    # bounded admission
    # slice reclaim: the departed job owns nothing at the end, and the
    # device ledger conservation holds across the reclaim handoffs
    assert "newbie" not in set(res.owner_final.values())
    assert set(res.owner_final) | res.excluded == \
        {d.index for d in cluster.devices}
    assert any(h.from_job == "newbie" for h in res.handoffs)
    # every launched rollout is still accounted for after retirement
    r = res.per_job["newbie"]
    assert r.rollouts_launched == (r.rollouts_trained + r.dropped +
                                   r.rollouts_in_buffer +
                                   r.rollouts_generating)


def test_multi_sim_admission_retry_tick(pool, cluster):
    """With a slow pool replan, the periodic admission tick re-prices the
    queued arrival while it waits — retries are recorded and the job is
    still admitted and completes (the tick never double-books it)."""
    rp = PoolReplanner(cluster, elastic=ElasticConfig(replan_latency_s=30.0))
    arr = JobSpec("ticked", PAPER_MODELS["1.5B"], P, _cfg(), weight=1.0)
    res = MultiJobSimulator(pool, MultiSimConfig(
        n_steps=8, arrivals=[JobArrival(arr, t_submit=40.0, n_steps=3)],
        depart_on_completion=True, replanner=rp,
        admission=AdmissionConfig(retry_interval_s=5.0),
        check_invariants=True)).run()
    assert res.per_job["ticked"].steps == 3
    # the 30s replan latency can leave the departure commit past the last
    # event — finished either way, never stuck PENDING
    assert res.records["ticked"].state in (JobState.DRAINING,
                                           JobState.COMPLETED)
    assert res.records["ticked"].retries >= 1


# --------------------------------------------------- state reclaim (sat 4)
def test_pool_staleness_registry_remove_job():
    reg = PoolStalenessRegistry()
    ca = reg.add_job("a", StalenessConfig(eta=3, rollouts_per_step=4))
    cb = reg.add_job("b", StalenessConfig(eta=1, rollouts_per_step=4))
    ca.launch(4)
    ca.bump_version()
    reg.record_handoff("a", "b")
    gone = reg.remove_job("a")
    assert gone is ca and "a" not in reg.controllers
    reg.assert_bounds()                        # no dangling stream checked
    assert reg.max_staleness() == {"b": 0}
    assert reg.handoff_history()               # audit trail outlives the job
    with pytest.raises(KeyError):
        reg.remove_job("a")
    reg.add_job("a")                           # name is reusable after reclaim
    assert cb.plan_epoch == 1


def test_job_buffers_remove_job_requires_drain():
    bufs = JobBuffers()
    a = bufs.add_job("a", StalenessConfig(eta=2, rollouts_per_step=2))
    bufs.add_job("b", StalenessConfig(eta=1, rollouts_per_step=2))
    a.launch(2)
    for g in range(2):
        a.push(Rollout([1], [2], None, version=0, group_id=g))
    with pytest.raises(RuntimeError):          # in flight: refuse silent loss
        bufs.remove_job("a")
    a.pop_batch(2)                             # drain cleanly
    final = bufs.remove_job("a")
    assert final["in_flight"] == 0 and final["dropped"] == 0
    assert "a" not in bufs and bufs.jobs() == ["b"]
    with pytest.raises(KeyError):
        bufs.remove_job("a")


def test_job_buffers_force_remove_accounts_drops():
    bufs = JobBuffers()
    a = bufs.add_job("a", StalenessConfig(eta=2, rollouts_per_step=2))
    a.launch(3)                                # 2 will buffer, 1 stays out
    for g in range(2):
        a.push(Rollout([1], [2], None, version=0, group_id=g))
    final = bufs.remove_job("a", force=True)   # preemption path
    assert final["dropped"] == 3               # nothing vanishes silently
    assert final["in_flight"] == 0 and final["size"] == 0
    assert "a" not in bufs
