"""Pallas kernel sweeps: shapes × dtypes vs the pure-jnp ref oracles.

All kernels run interpret=True (the CPU contract); the same entry points
compile on TPU.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.ssm_scan.ops import mlstm_scan
from repro.kernels.ssm_scan.ref import mlstm_scan_ref


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,Sq,Sk,H,Hkv,D", [
    (1, 16, 16, 1, 1, 8),
    (2, 40, 40, 4, 2, 16),
    (2, 33, 65, 4, 4, 24),       # non-multiple shapes → padding paths
    (1, 128, 128, 8, 2, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 9),
                                           (False, None)])
def test_flash_attention_sweep(B, Sq, Sk, H, Hkv, D, dtype, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(B * Sq + D), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D), dtype)
    qp = jnp.broadcast_to(jnp.arange(Sq), (B, Sq))
    kp = jnp.broadcast_to(jnp.arange(Sk), (B, Sk))
    out = flash_attention(q, k, v, causal, window, None, 16, 16, True)
    ref = attention_ref(q, k, v, q_positions=qp, k_positions=kp,
                        causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("block_q,block_k", [
    (8, 32),                     # asymmetric, q-minor
    (32, 8),                     # asymmetric, k-minor
    (64, 16),                    # the autotuner's small-seq candidates
])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 20)])
def test_flash_attention_block_configs(block_q, block_k, causal, window):
    """Tuned (non-default) tilings must match the reference oracle — the
    autotuner may pick any of these, so correctness can't be a property of
    the 128×128 default alone."""
    B, Sq, Sk, H, Hkv, D = 2, 48, 80, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(block_q * 100 + block_k), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, D))
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D))
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D))
    qp = jnp.broadcast_to(jnp.arange(Sq), (B, Sq))
    kp = jnp.broadcast_to(jnp.arange(Sk), (B, Sk))
    out = flash_attention(q, k, v, causal, window, None, block_q, block_k,
                          True)
    ref = attention_ref(q, k, v, q_positions=qp, k_positions=kp,
                        causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_grad_matches_ref():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (1, 24, 2, 8))
    k = jax.random.normal(ks[1], (1, 24, 2, 8))
    v = jax.random.normal(ks[2], (1, 24, 2, 8))
    qp = jnp.broadcast_to(jnp.arange(24), (1, 24))

    def f_kernel(q, k, v):
        return jnp.sum(flash_attention(q, k, v, True, None, None, 8, 8,
                                       True) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(attention_ref(q, k, v, q_positions=qp,
                                     k_positions=qp, causal=True) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("B,H,Hkv,D,C", [
    (1, 2, 2, 8, 8),
    (2, 4, 2, 16, 24),
    (2, 8, 1, 64, 40),           # MQA
    (3, 6, 3, 20, 17),           # odd sizes
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 8])
def test_decode_attention_sweep(B, H, Hkv, D, C, dtype, window):
    ks = jax.random.split(jax.random.PRNGKey(B * C + H), 3)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k = jax.random.normal(ks[1], (B, C, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, C, Hkv, D), dtype)
    q_pos = jnp.arange(B, dtype=jnp.int32) * 3 + C // 2
    k_pos = jnp.broadcast_to(jnp.arange(C), (B, C)).astype(jnp.int32)
    k_pos = jnp.where(k_pos <= q_pos[:, None], k_pos, -(2 ** 30))
    out = decode_attention(q, k, v, q_pos, k_pos, window=window,
                           block_c=8, interpret=True)
    ref = decode_attention_ref(q, k, v, q_pos, k_pos, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("block_c", [8, 16])
@pytest.mark.parametrize("window", [None, 6])
def test_decode_attention_ragged_lengths(block_c, window):
    """Non-uniform cache lengths per row (the serving reality the uniform
    sweep above never exercises): each row has its own valid prefix, the
    rest of the cache is empty slots (-2^30) holding garbage values."""
    B, H, Hkv, D, C = 4, 4, 2, 16, 40
    lens = np.array([1, 7, 23, 40])
    ks = jax.random.split(jax.random.PRNGKey(block_c + (window or 0)), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, C, Hkv, D))
    v = jax.random.normal(ks[2], (B, C, Hkv, D))
    # poison the dead slots: masked entries must never leak
    slot = np.broadcast_to(np.arange(C), (B, C))
    dead = slot >= lens[:, None]
    k = jnp.where(jnp.asarray(dead)[:, :, None, None], 1e6, k)
    v = jnp.where(jnp.asarray(dead)[:, :, None, None], -1e6, v)
    q_pos = jnp.asarray(lens - 1, jnp.int32)
    k_pos = jnp.where(jnp.asarray(dead), -(2 ** 30),
                      jnp.asarray(slot, jnp.int32))
    out = decode_attention(q, k, v, q_pos, k_pos, window=window,
                           block_c=block_c, interpret=True)
    ref = decode_attention_ref(q, k, v, q_pos, k_pos, window=window)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_ring_layout():
    """SWA ring-buffer layout: a row's valid slots are not a prefix —
    positions wrap around the ring, empty slots interleave arbitrarily."""
    B, H, Hkv, D, C = 2, 4, 2, 16, 16
    W = 10
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, C, Hkv, D))
    v = jax.random.normal(ks[2], (B, C, Hkv, D))
    # row 0: decoded 21 tokens through a ring of 16 → slots hold positions
    # (pos % C); row 1: only 5 tokens, rest empty
    kp = np.full((B, C), -(2 ** 30), np.int64)
    for s in range(C):
        pos = 21 - 1 - ((21 - 1 - s) % C)
        if 0 <= pos:
            kp[0, s] = pos
    kp[1, :5] = np.arange(5)
    q_pos = jnp.asarray([20, 4], jnp.int32)
    k_pos = jnp.asarray(kp, jnp.int32)
    out = decode_attention(q, k, v, q_pos, k_pos, window=W, block_c=8,
                           interpret=True)
    ref = decode_attention_ref(q, k, v, q_pos, k_pos, window=W)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,S,H,D,chunk", [
    (1, 16, 1, 8, 8),
    (2, 50, 4, 16, 16),          # padding path
    (1, 64, 2, 32, 32),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mlstm_scan_sweep(B, S, H, D, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(S + D), 5)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, H, D), dtype)
    v = jax.random.normal(ks[2], (B, S, H, D), dtype)
    ig = jax.random.normal(ks[3], (B, S, H))
    fg = jax.random.normal(ks[4], (B, S, H)) + 2.0
    out = mlstm_scan(q, k, v, ig, fg, chunk=chunk, interpret=True)

    def flat(x):
        return jnp.moveaxis(x, 2, 1).reshape(B * H, S, *x.shape[3:])

    ref = mlstm_scan_ref(flat(q.astype(jnp.float32)),
                         flat(k.astype(jnp.float32)),
                         flat(v.astype(jnp.float32)), flat(ig), flat(fg))
    ref = jnp.moveaxis(ref.reshape(B, H, S, D), 1, 2)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4)
