"""Per-arch smoke tests (reduced configs) + decode-consistency checks.

Every assigned architecture instantiates its REDUCED same-family config and
runs one forward + one GRPO train step on CPU, asserting shapes and no
NaNs.  The FULL configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, PAPER_ARCHS, get_smoke_config
from repro.models.api import get_model, train_input_specs
from repro.optim.adamw import adamw_init
from repro.rl.grpo import make_train_step

ALL = ASSIGNED_ARCHS + PAPER_ARCHS


def _dummy_batch(cfg, B=2, S=24, rng=None):
    rng = rng or jax.random.PRNGKey(0)
    batch = {
        "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab),
        "loss_mask": jnp.ones((B, S), jnp.float32),
        "advantages": jnp.array([1.0, -1.0] * (B // 2), jnp.float32)[:B],
        "behavior_logp": -2.0 * jnp.ones((B, S), jnp.float32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            rng, (B, cfg.encoder_seq, cfg.enc_dim))
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            rng, (B, cfg.encoder_seq, cfg.enc_dim))
    return batch


@pytest.mark.parametrize("arch", ALL)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    batch = _dummy_batch(cfg)
    logits = model.forward(params, cfg, batch["tokens"],
                           frames=batch.get("frames"),
                           patches=batch.get("patches"))
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    opt = adamw_init(params)
    step = make_train_step(cfg)
    batch = _dummy_batch(cfg)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, new_params)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


# whisper-small is the slowest prefill/decode param (~11s on CI hardware):
# marked slow so the default CI run stays inside its budget.
@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "qwen2.5-3b",
                                  "qwen3-moe-235b-a22b", "xlstm-1.3b",
                                  "hymba-1.5b",
                                  pytest.param("whisper-small",
                                               marks=pytest.mark.slow),
                                  "internvl2-2b"])
def test_prefill_decode_matches_forward(arch):
    """serve path == train path: prefill(p) + decode steps reproduce the
    full forward's logits at every generated position."""
    cfg = get_smoke_config(arch)
    if cfg.family == "moe":
        from repro.models import moe
        moe_cap = moe.CAPACITY_FACTOR
        moe.CAPACITY_FACTOR = 100.0      # dropping is group-dependent
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    B, S, Sp = 2, 16, 8
    rng = jax.random.PRNGKey(1)
    toks = jax.random.randint(rng, (B, S), 3, cfg.vocab)
    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = jax.random.normal(
            rng, (B, cfg.encoder_seq, cfg.enc_dim))
    if cfg.family == "vlm":
        extras["patches"] = jax.random.normal(
            rng, (B, cfg.encoder_seq, cfg.enc_dim))

    full = model.forward(params, cfg, toks, **extras)
    lg, cache = model.prefill(params, cfg, toks[:, :Sp], max_len=S, **extras)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, Sp - 1]),
                               atol=2e-3, rtol=2e-3)
    for i in range(Sp, S):
        lg, cache = model.decode_step(params, cfg, cache, toks[:, i],
                                      jnp.full((B,), i, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, i]),
                                   atol=2e-3, rtol=2e-3)
    if cfg.family == "moe":
        moe.CAPACITY_FACTOR = moe_cap


@pytest.mark.slow       # slowest model-forward test (~25s): 32 decode steps
def test_swa_ring_buffer_long_decode():
    """SWA archs decode past the window with a ring cache (long_500k path)."""
    cfg = get_smoke_config("h2o-danube-1.8b")   # window 16
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    B, S = 1, 40                                 # decode well past window
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 3, cfg.vocab)
    full = model.forward(params, cfg, toks)
    _, cache = model.prefill(params, cfg, toks[:, :8], max_len=24)
    assert cache["k"].shape[2] == cfg.attn_window   # ring of W, not S
    for i in range(8, S):
        lg, cache = model.decode_step(params, cfg, cache, toks[:, i],
                                      jnp.full((B,), i, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, i]),
                                   atol=2e-3, rtol=2e-3)


def test_unroll_layers_equivalence():
    cfg = get_smoke_config("qwen2.5-3b")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab)
    a = model.forward(params, cfg, toks)
    b = model.forward(params, cfg.replace(unroll_layers=True), toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
