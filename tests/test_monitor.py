"""Online health monitoring: detector units on synthetic streams, the
zero-overhead guarantee (monitor off/on bit-identity), and the e2e
injected-straggler scenario where monitor-triggered replanning acts
strictly earlier than the throughput EWMA and wins on throughput."""
import pytest

from repro.core.cluster import paper_heterogeneous
from repro.core.cost_model import LengthDistribution
from repro.core.jobs import TrendConfig
from repro.core.model_spec import PAPER_MODELS
from repro.core.pool import JobSpec, schedule_pool
from repro.core.scheduler import SchedulerConfig, schedule
from repro.core.staleness import StalenessConfig
from repro.obs import (Alert, BurnWindow, HealthMonitor, MetricsRegistry,
                       MonitorConfig, SLOSpec, Tracer, burn_rate,
                       classify_burn)
from repro.sim import (AsyncRLSimulator, ElasticConfig, JobStraggler,
                       MultiJobSimulator, MultiSimConfig, PoolReplanner,
                       SimConfig)

P = LengthDistribution(mean_len=1024, prompt_len=128)
SCHED_CFG = SchedulerConfig(tokens_per_step=2 ** 18, stable_iters=3,
                            max_iters=12, adapt_delta=False)


def _mon(**kw) -> HealthMonitor:
    """Monitor with a short window/poll so unit tests stay compact."""
    base = dict(window_s=30.0, poll_interval_s=2.0, cooldown_s=30.0)
    base.update(kw)
    return HealthMonitor(MonitorConfig(**base))


# ================================================================ detectors
def test_straggler_detector_flags_slow_replica():
    mon = _mon()
    for t in range(10, 30, 2):
        for rep in range(4):
            rate = 20.0 if rep == 0 else 100.0     # r0 is 5× slower
            mon.on_gen_span("j", rep, float(t), 100.0 / rate, 100.0)
    alerts = mon.poll(30.0)
    strag = [a for a in alerts if a.detector == "straggler"]
    assert len(strag) == 1
    a = strag[0]
    assert a.key == "j/r0"
    assert a.severity == "critical"                # z far past 2× threshold
    assert a.evidence["replica"] == 0
    assert a.evidence["job"] == "j"
    assert a.evidence["z"] < -mon.cfg.straggler_z
    assert a.evidence["rate"] < a.evidence["fleet_rate"]
    d = a.to_dict()
    assert d["detector"] == "straggler" and d["evidence"]["replica"] == 0


def test_straggler_detector_quiet_on_healthy_fleet():
    mon = _mon()
    for t in range(0, 30, 2):
        for rep in range(6):
            rate = 100.0 + rep              # mild spread, no outlier
            mon.on_gen_span("j", rep, float(t), 100.0 / rate, 100.0)
    assert mon.poll(30.0) == []


def test_straggler_detector_needs_peers():
    mon = _mon()                            # min_peers=3: 2 replicas can't
    for t in range(0, 30, 2):               # establish a fleet distribution
        mon.on_gen_span("j", 0, float(t), 1.0, 10.0)
        mon.on_gen_span("j", 1, float(t), 1.0, 100.0)
    assert mon.poll(30.0) == []


def test_buffer_detector_gen_ahead_and_train_starved():
    mon = _mon()
    for t in range(0, 20, 2):               # depth pinned at capacity +
        mon.on_buffer("a", float(t), 95, 100)      # capacity stalls
        mon.on_stall("a", float(t), "capacity")
        mon.on_buffer("b", float(t), 2, 100)       # starved + data stalls
        mon.on_stall("b", float(t), "data")
    alerts = mon.poll(20.0)
    modes = {a.evidence["job"]: a.evidence["mode"] for a in alerts
             if a.detector == "buffer"}
    assert modes == {"a": "gen_ahead", "b": "train_starved"}


def test_buffer_detector_quiet_on_balance():
    mon = _mon()
    for t in range(0, 20, 2):
        mon.on_buffer("a", float(t), 50, 100)      # mid depth, no stalls
    assert mon.poll(20.0) == []


def test_staleness_detector_burns_near_eta():
    mon = _mon()
    for i in range(16):                     # everything at η: 100% bad
        mon.on_staleness("j", float(i), 4, eta=4)
    alerts = [a for a in mon.poll(16.0) if a.detector == "staleness"]
    assert len(alerts) == 1
    # objective 0.75 → budget 0.25 → burn 4× on a 100%-bad window
    assert alerts[0].severity == "warn"
    assert alerts[0].evidence["burn"] == pytest.approx(4.0)
    assert alerts[0].evidence["bad_frac"] == 1.0
    mon2 = _mon()
    for i in range(16):                     # all fresh: no burn
        mon2.on_staleness("j", float(i), 0, eta=4)
    assert [a for a in mon2.poll(16.0) if a.detector == "staleness"] == []


def test_bubble_detector_alerts_on_drift():
    mon = _mon(detect_straggler=False, detect_buffer=False,
               detect_staleness=False, detect_admission=False,
               bubble_ref_polls=2, bubble_drift=0.2)
    t = 0.0
    for _ in range(4):                      # dense polls lock a ~0 reference
        for s in range(30):
            mon.on_stage_span("train", t + s, 1.0)
        t += 30.0
        assert mon.poll(t) == []
    for _ in range(3):                      # stage goes 80% idle
        for s in range(0, 30, 5):
            mon.on_stage_span("train", t + s, 1.0)
        t += 30.0
    alerts = mon.poll(t)
    assert any(a.detector == "bubble" and a.key == "train" for a in alerts)


def test_admission_detector_burns_on_slow_admissions():
    mon = _mon()
    for i in range(8):
        mon.on_admission(f"job{i}", float(i), 120.0)   # all above 60s SLO
    alerts = [a for a in mon.poll(8.0) if a.detector == "admission"]
    assert len(alerts) == 1 and alerts[0].key == "pool"
    mon2 = _mon()
    for i in range(8):
        mon2.on_admission(f"job{i}", float(i), 5.0)
    assert [a for a in mon2.poll(8.0) if a.detector == "admission"] == []


def test_cooldown_suppresses_repeat_alerts():
    mon = _mon(cooldown_s=100.0)
    for t in range(10, 30, 2):
        for rep in range(4):
            rate = 20.0 if rep == 0 else 100.0
            mon.on_gen_span("j", rep, float(t), 100.0 / rate, 100.0)
    assert len(mon.poll(30.0)) == 1
    for t in range(30, 40, 2):              # still straggling, inside
        for rep in range(4):                # the cooldown window
            rate = 20.0 if rep == 0 else 100.0
            mon.on_gen_span("j", rep, float(t), 100.0 / rate, 100.0)
    assert mon.poll(40.0) == []
    assert len(mon.alerts) == 1


def test_reset_job_clears_evidence_but_not_cooldown():
    mon = _mon()
    for t in range(10, 30, 2):
        for rep in range(4):
            rate = 20.0 if rep == 0 else 100.0
            mon.on_gen_span("j", rep, float(t), 100.0 / rate, 100.0)
    assert len(mon.poll(30.0)) == 1
    mon.reset_job("j")                      # plan swap: new fleet
    assert mon.poll(32.0) == []             # stale evidence gone


# --------------------------------------------------------------- SLO / burn
def test_burn_window_and_classification():
    slo = SLOSpec("x", objective=0.9, description="")
    bw = BurnWindow(slo, window_s=10.0)
    for t in range(10):
        bw.observe(float(t), bad=(t % 2 == 0))     # 50% bad, budget 10%
    assert bw.n(9.0) == 10
    assert bw.bad_frac(9.0) == pytest.approx(0.5)
    assert bw.burn(9.0) == pytest.approx(5.0)
    assert classify_burn(5.0) == "warn"
    assert classify_burn(15.0) == "critical"
    assert classify_burn(0.5) == ""
    assert burn_rate(0.5, slo) == pytest.approx(5.0)
    bw.observe(25.0, bad=False)             # old samples age out
    assert bw.n(25.0) == 1
    with pytest.raises(ValueError):
        SLOSpec("bad", objective=1.5, description="")


def test_monitor_consumes_registry_snapshots():
    """observe_registry turns staleness histograms + η gauges into the
    same burn-window evidence the direct feeds produce."""
    mx = MetricsRegistry()
    mx.gauge("buffer/eta").set(4)
    h = mx.histogram("buffer/staleness")
    for _ in range(16):
        h.observe(4.0)                      # every rollout at the bound
    mon = _mon(detect_straggler=False, detect_buffer=False,
               detect_bubble=False, detect_admission=False)
    mon.observe_registry(mx, t=10.0)
    alerts = [a for a in mon.poll(12.0) if a.detector == "staleness"]
    assert len(alerts) == 1
    # bucket-resolution estimate: 4.0 lands in (2, 4], frac ≥ 3 of that
    # bucket interpolates to (4−3)/(4−2) = 0.5 — enough to burn 2×
    assert alerts[0].evidence["bad_frac"] == pytest.approx(0.5)
    assert alerts[0].evidence["burn"] >= 1.0


def test_monitor_consumes_trace_stream():
    """A Tracer sink streams replica spans into the straggler detector."""
    tr = Tracer()
    mon = HealthMonitor(MonitorConfig(window_s=30.0, poll_interval_s=2.0),
                        tracer=tr)
    tr.add_sink(mon.on_trace_event)
    for t in range(10, 30, 2):
        for rep in range(4):
            rate = 20.0 if rep == 0 else 100.0
            tr.span("replica", f"j/r{rep}", "generate", float(t),
                    100.0 / rate, tokens=100.0)
    alerts = mon.poll(30.0)
    assert [a.key for a in alerts if a.detector == "straggler"] == ["j/r0"]
    # the alert itself lands back in the trace as an instant event
    assert any(ev[1] == "health" and ev[2] == "straggler"
               and ev[3] == "j/r0"
               for ev in tr._events if ev[0] == "i"), \
        "alert not recorded as a trace instant"


# ========================================================= zero overhead
SIM = dict(n_steps=8, rollouts_per_step=32, eta=4, reward_cost_s=0.1)


@pytest.fixture(scope="module")
def plan():
    return schedule(PAPER_MODELS["1.5B"], paper_heterogeneous(16, 16), P,
                    SCHED_CFG)


def test_single_job_sim_bit_identical_with_monitor(plan):
    off = AsyncRLSimulator(plan, P, SimConfig(**SIM, seed=3)).run()
    mon = HealthMonitor()
    on = AsyncRLSimulator(plan, P, SimConfig(**SIM, seed=3,
                                             monitor=mon)).run()
    assert on.wall_time_s == off.wall_time_s
    assert on.tokens_consumed == off.tokens_consumed
    assert on.rollouts_launched == off.rollouts_launched
    assert on.steps == off.steps
    assert on.mean_staleness == off.mean_staleness
    assert mon.polls > 0                    # the monitor did observe the run


def _pool_and_cluster():
    cluster = paper_heterogeneous(8, 24)
    cfg4 = SchedulerConfig(tokens_per_step=2 ** 18, stable_iters=3,
                           max_iters=12, adapt_delta=False,
                           staleness=StalenessConfig(eta=4))
    cfg2 = SchedulerConfig(tokens_per_step=2 ** 18, stable_iters=3,
                           max_iters=12, adapt_delta=False,
                           staleness=StalenessConfig(eta=2))
    jobs = [JobSpec("j1.5b", PAPER_MODELS["1.5B"], P, cfg4, weight=1.0),
            JobSpec("j7b", PAPER_MODELS["7B"], P, cfg2, weight=4.0)]
    return schedule_pool(jobs, cluster), cluster


@pytest.fixture(scope="module")
def pool_cluster():
    return _pool_and_cluster()


def test_multi_job_sim_bit_identical_with_monitor(pool_cluster):
    pool, _ = pool_cluster
    base = dict(n_steps=6, rollouts_per_step=32, check_invariants=True)
    off = MultiJobSimulator(pool, MultiSimConfig(**base)).run()
    mon = HealthMonitor()
    on = MultiJobSimulator(pool, MultiSimConfig(**base,
                                                monitor=mon)).run()
    assert on.wall_time_s == off.wall_time_s
    assert on.owner_final == off.owner_final
    for n in off.per_job:
        assert on.per_job[n].tokens_consumed == off.per_job[n].tokens_consumed
        assert on.per_job[n].rollouts_launched == \
            off.per_job[n].rollouts_launched
    assert mon.polls > 0


def test_paged_engine_tokens_bit_identical_with_monitor():
    import jax
    from repro.data.tasks import MathTaskGenerator, Tokenizer
    from repro.models.api import ModelConfig, get_model
    from repro.rl.rollout import GenConfig
    from repro.rl.weight_sync import WeightStore
    from repro.serve import PagedEngine, ServeConfig

    tok = Tokenizer()
    tiny = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                       n_heads=4, n_kv_heads=2, d_ff=64,
                       vocab=tok.vocab_size, dtype="float32", remat=False)
    model = get_model(tiny)
    store = WeightStore()
    store.publish(model.init(jax.random.PRNGKey(0), tiny))
    tasks = MathTaskGenerator(seed=0).batch(4)
    gen = GenConfig(max_new_tokens=12)
    sc = ServeConfig(max_slots=4, max_len=96)

    def run(monitor):
        eng = PagedEngine(tiny, store, gen, sc, rng_seed=1, monitor=monitor)
        rollouts, _ = eng.generate(tasks)
        return [r.completion_ids for r in rollouts]

    mon = HealthMonitor()
    assert run(None) == run(mon)
    assert mon._stages                      # decode/prefill spans did land


# ================================================== e2e: monitor beats EWMA
def test_monitor_replan_beats_ewma_on_injected_straggler(pool_cluster):
    """Acceptance (ISSUE 9): three near-dead replicas are injected into
    the heavier job.  The monitor's z-score detector flags them from
    span-rate evidence at launch time; the EWMA only reacts after enough
    slow *train steps* drag its smoothed throughput under threshold.
    Both runs end up excluding the same straggling replica — the monitor
    just gets there strictly earlier, so it spends less wall-clock in
    the degraded regime and wins on end-to-end throughput, with the
    device conservation ledger intact."""
    pool, cluster = pool_cluster
    stragglers = [JobStraggler("j7b", i, factor=0.01, t_start=150.0)
                  for i in (0, 1, 2)]
    base = dict(n_steps=14, rollouts_per_step=256, stragglers=stragglers,
                check_invariants=True)
    # cum_factor 0.01 stays above straggler_threshold=0.005: the builtin
    # threshold trigger stays silent and the EWMA is the only baseline
    # detector in play
    elastic = ElasticConfig(replan_latency_s=4.0, straggler_threshold=0.005)
    trend = TrendConfig(alpha=0.5, min_samples=3, threshold=0.85)

    ewma = MultiJobSimulator(pool, MultiSimConfig(
        **base, replanner=PoolReplanner(cluster, elastic=elastic),
        trend=trend)).run()
    mon = HealthMonitor(MonitorConfig(detect_buffer=False,
                                      detect_bubble=False,
                                      detect_staleness=False))
    mres = MultiJobSimulator(pool, MultiSimConfig(
        **base, replanner=PoolReplanner(cluster, elastic=elastic),
        trend=trend, monitor=mon, monitor_replan=True)).run()

    # EWMA-only: the trend detector did fire (this baseline is live)
    ewma_t = [t.time for t in ewma.replan_triggers if t.reason == "trend"]
    assert ewma_t, "EWMA baseline never triggered — scenario broken"
    # monitor: the straggler alert routed into the replan path...
    mon_t = [t.time for t in mres.replan_triggers
             if t.reason == "monitor_straggler"]
    assert mon_t, "monitor never triggered a replan"
    assert any(a.detector == "straggler" and a.severity == "critical"
               for a in mon.alerts)
    # ...strictly earlier than the EWMA would have
    assert min(mon_t) < min(ewma_t)
    # and the earlier replan wins end-to-end
    assert mres.pool_swaps >= 1 and ewma.pool_swaps >= 1
    w = {"j1.5b": 1.0, "j7b": 4.0}
    assert mres.per_job["j7b"].throughput_tps > \
        ewma.per_job["j7b"].throughput_tps
    assert mres.weighted_throughput(w) > ewma.weighted_throughput(w)
    assert mres.wall_time_s <= ewma.wall_time_s
    # conservation: per-job rollout ledgers and the device ledger
    for res in (ewma, mres):
        for r in res.per_job.values():
            assert r.rollouts_launched == (r.rollouts_trained + r.dropped +
                                           r.rollouts_in_buffer +
                                           r.rollouts_generating)
        assert set(res.owner_final) | res.excluded == \
            {d.index for d in cluster.devices}
        assert not set(res.owner_final) & res.excluded


def test_monitor_off_means_no_replan_interference(pool_cluster):
    """monitor_replan=False: an attached monitor observes and alerts but
    never actuates — sim results match the no-monitor run exactly."""
    pool, cluster = pool_cluster
    stragglers = [JobStraggler("j7b", 0, factor=0.01, t_start=60.0)]
    base = dict(n_steps=6, rollouts_per_step=64, stragglers=stragglers,
                check_invariants=True)
    elastic = ElasticConfig(replan_latency_s=4.0, straggler_threshold=0.005)
    off = MultiJobSimulator(pool, MultiSimConfig(
        **base, replanner=PoolReplanner(cluster, elastic=elastic))).run()
    mon = HealthMonitor()
    on = MultiJobSimulator(pool, MultiSimConfig(
        **base, replanner=PoolReplanner(cluster, elastic=elastic),
        monitor=mon)).run()
    assert on.wall_time_s == off.wall_time_s
    assert [t.time for t in on.replan_triggers] == \
        [t.time for t in off.replan_triggers]
    assert mon.polls > 0                    # it watched, it never steered


def test_monitor_replan_requires_replanner():
    pool, _ = _pool_and_cluster()
    with pytest.raises(ValueError):
        MultiJobSimulator(pool, MultiSimConfig(
            monitor=HealthMonitor(), monitor_replan=True))
