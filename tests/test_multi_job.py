"""Multi-job pool invariants: device conservation across jobs and swaps,
per-job η bounds under cross-job handoffs, arbitration determinism, and
the single-job wrapper contract (extends tests/test_elastic_replan.py
patterns to N jobs)."""
import pytest

from repro.core.cluster import Cluster, paper_heterogeneous
from repro.core.cost_model import LengthDistribution
from repro.core.graph_partition import ici_domains, subcluster
from repro.core.milp import enumerate_replica_configs, slice_node_widths
from repro.core.model_spec import PAPER_MODELS
from repro.core.pool import (JobSpec, PoolConfig, replan_pool,
                             schedule_pool)
from repro.core.scheduler import SchedulerConfig, schedule, schedule_slice
from repro.core.staleness import PoolStalenessRegistry, StalenessConfig
from repro.rl.buffer import JobBuffers
from repro.sim import (ElasticConfig, JobFailure, MultiJobSimulator,
                       MultiSimConfig, PoolReplanner, replica_device_map)

P = LengthDistribution(mean_len=1024, prompt_len=128)


def _cfg(eta: int = 4) -> SchedulerConfig:
    return SchedulerConfig(tokens_per_step=2 ** 18, stable_iters=3,
                           max_iters=12, adapt_delta=False,
                           staleness=StalenessConfig(eta=eta))


def _jobs():
    """Mixed scale and mixed η: the 7B job runs a tighter staleness budget."""
    return [JobSpec("j1.5b", PAPER_MODELS["1.5B"], P, _cfg(eta=4),
                    weight=1.0),
            JobSpec("j7b", PAPER_MODELS["7B"], P, _cfg(eta=2), weight=4.0)]


@pytest.fixture(scope="module")
def cluster():
    return paper_heterogeneous(8, 56)      # 1 H800 node + 7 H20 nodes


@pytest.fixture(scope="module")
def pool(cluster):
    return schedule_pool(_jobs(), cluster)


def _kill_one_node_of(pool_plan, cluster, job_name, t_fail=30.0):
    plan = pool_plan.plans[job_name]
    rmap = replica_device_map(cluster.subset(plan.infer_devices), plan)
    node = rmap[0][0].node
    fails = [JobFailure(job_name, i, t_fail=t_fail)
             for i, devs in enumerate(rmap) if devs and devs[0].node == node]
    assert fails
    return fails


def _run_with_failure(pool_plan, cluster, n_steps=8):
    rp = PoolReplanner(cluster, elastic=ElasticConfig(replan_latency_s=4.0))
    return MultiJobSimulator(pool_plan, MultiSimConfig(
        n_steps=n_steps, failures=_kill_one_node_of(pool_plan, cluster,
                                                    "j7b"),
        replanner=rp, check_invariants=True)).run()


# ---------------------------------------------------------------- ownership
def test_pool_plan_partitions_devices(pool, cluster):
    pool.assert_partition(cluster)
    # slices are ICI-domain granular: a machine never splits across jobs
    for dom in ici_domains(cluster):
        owners = {pool.owner[d.index] for d in dom}
        assert len(owners) == 1


def test_device_conservation_across_cross_job_swap(pool, cluster):
    res = _run_with_failure(pool, cluster)
    assert res.pool_swaps >= 1
    # owned ⊎ excluded == the initial device set, after every handoff
    owned = set(res.owner_final)
    assert owned | res.excluded == {d.index for d in cluster.devices}
    assert not owned & res.excluded
    for h in res.handoffs:
        assert h.from_job != h.to_job
        assert set(h.device_indices) <= owned
    # per-job rollout ledgers stay conserved too
    for r in res.per_job.values():
        assert r.rollouts_launched == (r.rollouts_trained + r.dropped +
                                       r.rollouts_in_buffer +
                                       r.rollouts_generating)


# ------------------------------------------------------------- η per job
def test_eta_bounds_hold_independently_across_handoff(pool, cluster):
    """Acceptance: each job's own η budget holds on both sides of a swap
    that moved devices *between* jobs."""
    res = _run_with_failure(pool, cluster)
    assert len(res.handoffs) >= 1           # a cross-job handoff happened
    for job in pool.jobs:
        r = res.per_job[job.name]
        assert r.max_staleness <= job.eta, (job.name, r.max_staleness)
        for s in r.swaps:
            assert s.max_staleness_before <= job.eta
            assert s.max_staleness_after <= job.eta
            assert s.t_commit >= s.t_request


def test_delta_pinned_per_job_across_pool_replan(pool, cluster):
    dead_node = cluster.subset(pool.plans["j7b"].infer_devices)[0].node
    survivors = Cluster([d for d in cluster.devices if d.node != dead_node],
                        cluster.cross_type_bw)
    new = replan_pool(pool, survivors, reason="failure")
    new.assert_partition(survivors)
    for job in pool.jobs:
        assert new.plans[job.name].delta == pool.plans[job.name].delta
    assert new.pool_epoch == pool.pool_epoch + 1
    # damaged/changed jobs carry replan provenance
    changed = [n for n in new.plans
               if new.plans[n].plan_epoch != pool.plans[n].plan_epoch]
    assert "j7b" in changed
    for n in changed:
        assert new.plans[n].provenance == "replan:failure"


# ------------------------------------------------------------- determinism
def test_arbitration_deterministic(cluster):
    a = schedule_pool(_jobs(), cluster)
    b = schedule_pool(_jobs(), cluster)
    assert a.signature() == b.signature()
    assert a.transfers == b.transfers


def test_multi_sim_deterministic_given_seed(pool, cluster):
    r1 = _run_with_failure(pool, cluster)
    r2 = _run_with_failure(pool, cluster)
    assert r1.wall_time_s == r2.wall_time_s
    assert r1.owner_final == r2.owner_final
    for n in r1.per_job:
        assert r1.per_job[n].tokens_consumed == r2.per_job[n].tokens_consumed
        assert r1.per_job[n].rollouts_launched == \
            r2.per_job[n].rollouts_launched


# ------------------------------------------------------ single-job wrapper
def test_schedule_wrapper_matches_slice_engine():
    cluster = paper_heterogeneous(16, 16)
    spec = PAPER_MODELS["1.5B"]
    via_pool = schedule(spec, cluster, P, _cfg())
    direct = schedule_slice(spec, cluster, P, _cfg())
    assert via_pool.signature() == direct.signature()
    assert via_pool.job == direct.job == "job0"


# ------------------------------------------------------- slice-aware MILP
def test_psi_enumeration_respects_slice_node_widths(cluster):
    # a slice that owns only 3 devices of an 8-wide H800 machine must not
    # host tp=4 replicas (TP is confined to one machine)
    h800 = cluster.devices_of_type("H800")[:3]
    widths = slice_node_widths(h800)
    assert widths == {"H800": 3}
    configs = enumerate_replica_configs(
        PAPER_MODELS["1.5B"], {"H800": 3}, P, node_widths=widths)
    assert configs
    assert all(max(cfg.tp_per_stage) <= 2 for cfg, _ in configs)


def test_arbitration_never_splits_a_machine(pool, cluster):
    res = _run_with_failure(pool, cluster)
    by_node = {}
    for d in cluster.devices:
        if d.index in res.owner_final:
            by_node.setdefault(d.node, set()).add(res.owner_final[d.index])
    for node, owners in by_node.items():
        assert len(owners) == 1, (node, owners)


# ------------------------------------------------- per-job buffers/versions
def test_job_buffers_handoff_bumps_epochs_not_versions():
    bufs = JobBuffers()
    a = bufs.add_job("a", StalenessConfig(eta=2, rollouts_per_step=2))
    b = bufs.add_job("b", StalenessConfig(eta=1, rollouts_per_step=2))
    a.launch(2)
    from repro.rl.buffer import Rollout
    for g in range(2):
        a.push(Rollout([1], [2], None, version=0, group_id=g))
    va, vb = a.version, b.version
    epochs = bufs.on_device_handoff("b", "a")
    assert epochs == {"a": 1, "b": 1}
    assert a.version == va and b.version == vb   # versions untouched
    assert len(a.pop_batch(2)) == 2              # η admission unaffected
    assert bufs.stats()["a"]["plan_swaps"] == 1
    with pytest.raises(ValueError):
        bufs.add_job("a")


def test_pool_staleness_registry_handoff():
    reg = PoolStalenessRegistry()
    ca = reg.add_job("a", StalenessConfig(eta=3, rollouts_per_step=4))
    cb = reg.add_job("b", StalenessConfig(eta=1, rollouts_per_step=4))
    ca.launch(4)
    ca.bump_version()
    log = reg.record_handoff("a", "b")
    assert log[0] == "a" and log[3] == "b"
    assert ca.plan_epoch == 1 and cb.plan_epoch == 1
    assert ca.version == 1 and cb.version == 0   # streams independent
    assert reg.handoff_history() == [log]
    ca.consume([1] * 4)
    reg.assert_bounds()                          # 0 ≤ η for both


# -------------------------------------------------- capacity-bound regime
def test_more_replicas_than_capacity_terminates(pool, cluster):
    """η·B capacity below the replica count must pause the surplus fleet,
    not spin the resume loop forever (both simulators share the fix)."""
    from repro.sim import AsyncRLSimulator, SimConfig
    plan = schedule_slice(PAPER_MODELS["1.5B"],
                          paper_heterogeneous(16, 16), P, _cfg())
    n_rep = len(AsyncRLSimulator(plan, P).replicas)
    cap_cfg = SimConfig(n_steps=4, rollouts_per_step=2, eta=1,
                        reward_cost_s=0.1, check_invariants=True)
    assert (cap_cfg.eta + 1) * cap_cfg.rollouts_per_step < n_rep
    res = AsyncRLSimulator(plan, P, cap_cfg).run()
    assert res.steps == 4
    multi = MultiJobSimulator(pool, MultiSimConfig(
        n_steps=2, rollouts_per_step=2, check_invariants=True)).run()
    for r in multi.per_job.values():
        assert r.steps == 2


# ----------------------------------------------------- starved-slice repair
def test_replan_repairs_fully_dead_slice():
    """Losing a job's entire slice must not abort the pool replan: the
    transfer loop donates surviving domains until the job is feasible
    again (feasible-count dominates the arbitration score)."""
    cluster = paper_heterogeneous(32, 32)
    pool = schedule_pool(_jobs(), cluster)
    dead = set(pool.job_devices("j7b"))
    survivors = Cluster([d for d in cluster.devices if d.index not in dead],
                        cluster.cross_type_bw)
    new = replan_pool(pool, survivors, reason="failure")
    new.assert_partition(survivors)
    assert new.job_devices("j7b"), "starved job was not repaired"
    assert new.plans["j7b"].delta == pool.plans["j7b"].delta
    used = set(new.plans["j7b"].train_devices) \
        | set(new.plans["j7b"].infer_devices)
    assert used <= {d.index for d in survivors.devices}


def test_replan_frozen_job_keeps_slice_and_gets_no_devices(pool, cluster):
    """A finished job is frozen out of arbitration: its plan and slice are
    carried over verbatim and the failed job recovers from elsewhere."""
    dead_node = cluster.subset(pool.plans["j7b"].infer_devices)[0].node
    survivors = Cluster([d for d in cluster.devices if d.node != dead_node],
                        cluster.cross_type_bw)
    new = replan_pool(pool, survivors, reason="failure",
                      frozen=["j1.5b"])
    assert new.plans["j1.5b"] is pool.plans["j1.5b"]
    assert new.job_devices("j1.5b") == pool.job_devices("j1.5b")
    assert new.plans["j7b"].plan_epoch == pool.plans["j7b"].plan_epoch + 1
    with pytest.raises(ValueError):
        replan_pool(pool, survivors, frozen=["j1.5b", "j7b"])


# ------------------------------------------------------------- seed repair
def test_pool_rejects_undersized_pools():
    cluster = paper_heterogeneous(8, 8)          # 2 domains, 2 jobs × 2 min
    with pytest.raises(RuntimeError):
        schedule_pool(_jobs(), cluster,
                      PoolConfig(min_domains_per_job=2))
