"""Observability layer (ISSUE 8): tracer, metrics registry, analyzer,
structured logging, and the zero-overhead guarantee.

The load-bearing properties:

  * tracer — span/instant/counter events export to valid Chrome-trace
    JSON, B/E spans nest per (group, track) with end-without-begin a
    typed error;
  * metrics — counters/gauges/histograms snapshot and delta correctly;
    ``EngineReport`` round-trips through the registry (satellite 3);
  * zero overhead — with tracing/metrics OFF (the default) the
    simulator's results, the engine's token streams, and the pool
    scheduler's plans are bit-identical to a run that never imported
    the tracer; with tracing ON nothing changes either (hooks only
    observe);
  * conservation — for any seed, trace-derived replica busy time equals
    the simulator's ledger exactly and trace-derived throughput matches
    within the analyzer's 1% gate (property test);
  * control plane — admission decisions and latency land in the
    registry; buffer staleness lands per consumed rollout.
"""
import json

import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # minimal envs: seeded-sampling shim
    from _prop import given, settings, st

from repro.core.cluster import paper_heterogeneous
from repro.core.cost_model import LengthDistribution
from repro.core.jobs import AdmissionConfig, ControlPlane
from repro.core.model_spec import PAPER_MODELS
from repro.core.pool import JobSpec, PoolPlan, schedule_pool
from repro.core.scheduler import SchedulerConfig, schedule
from repro.core.staleness import StalenessConfig
from repro.obs import (MetricsRegistry, TraceError, Tracer, analyze_trace,
                       check_report, snapshot_delta)
from repro.obs import log as obs_log
from repro.obs.analyze import main as analyze_main
from repro.rl.buffer import Rollout, RolloutBuffer
from repro.sim import AsyncRLSimulator, SimConfig

SPEC = PAPER_MODELS["1.5B"]
P = LengthDistribution(mean_len=1024, prompt_len=128)


@pytest.fixture(scope="module")
def plan():
    return schedule(SPEC, paper_heterogeneous(8, 8), P,
                    SchedulerConfig(tokens_per_step=2**18, stable_iters=3,
                                    max_iters=12, adapt_delta=False))


# ---------------------------------------------------------------- tracer
def test_tracer_chrome_export_roundtrip(tmp_path):
    tr = Tracer(meta={"who": "test"})
    tr.span("stage", "train", "step", 1.0, 0.5, tokens=64)
    tr.instant("stage", "sync", "publish", 1.5, version=2)
    tr.counter("sim", "buffer", 1.0, depth=3)
    p = tmp_path / "t.json"
    tr.dump(str(p))
    doc = json.loads(p.read_text())
    assert doc == tr.to_chrome()
    evs = doc["traceEvents"]
    phases = {e["ph"] for e in evs}
    assert {"X", "i", "C", "M"} <= phases
    x = next(e for e in evs if e["ph"] == "X")
    assert x["ts"] == pytest.approx(1.0e6) and x["dur"] == pytest.approx(5e5)
    assert x["args"]["tokens"] == 64
    assert doc["otherData"]["who"] == "test"
    # M metadata names the (group, track) swimlanes
    names = {e["args"].get("name") for e in evs if e["ph"] == "M"}
    assert {"stage", "train", "sync", "sim", "buffer"} <= names


def test_tracer_begin_end_nesting_and_errors():
    tr = Tracer()
    tr.begin("engine", "loop", "step", 0.0)
    tr.begin("engine", "loop", "inner", 0.1)
    assert tr.end("engine", "loop", 0.2) == "inner"
    assert tr.end("engine", "loop", 0.3) == "step"
    assert tr.open_spans() == {}
    with pytest.raises(TraceError):
        tr.end("engine", "loop", 0.4)          # end without begin


# --------------------------------------------------------------- metrics
def test_metrics_snapshot_and_delta():
    mx = MetricsRegistry()
    mx.counter("a").inc(3)
    mx.gauge("g").set(7.5)
    h = mx.histogram("h", buckets=(0, 10, 100))
    for v in (5, 50, 500):
        h.observe(v)
    prev = mx.snapshot()
    mx.counter("a").inc(2)
    h.observe(5)
    d = mx.delta(prev)
    assert d["counters"]["a"] == 2.0
    assert d["gauges"]["g"] == 7.5              # gauges keep current
    assert d["histograms"]["h"]["counts"] == [0, 1, 0, 0]
    assert d["histograms"]["h"]["count"] == 1
    # module-level helper agrees
    assert snapshot_delta(mx.snapshot(), prev) == d


def test_metrics_histogram_buckets():
    mx = MetricsRegistry()
    h = mx.histogram("s")                       # powers-of-two defaults
    for v in (0, 1, 3, 1000, 10**6):
        h.observe(v)
    snap = mx.snapshot()["histograms"]["s"]
    assert sum(snap["counts"]) == 5
    assert snap["counts"][-1] == 1              # overflow bucket
    assert h.mean == pytest.approx((0 + 1 + 3 + 1000 + 10**6) / 5)


def test_engine_report_roundtrips_through_registry():
    """Satellite 3: EngineReport.from_stats rides the metrics registry,
    carrying slot occupancy and bt-upload counts without reaching into
    EngineStats fields."""
    from repro.serve import EngineReport
    from repro.serve.engine import EngineStats
    stats = EngineStats(max_slots=8)
    stats.decode_steps = 100
    stats.decode_slot_steps = 640               # 80% slot occupancy
    stats.tokens_generated = 640
    stats.bt_uploads = 7
    rep = EngineReport.from_stats(stats, "TPUv5e", tokens_per_sec=123.0)
    assert rep.slot_occupancy == pytest.approx(stats.slot_occupancy)
    assert rep.batch_slots == 8
    assert rep.decode_steps == 100
    assert rep.bt_uploads == 7
    assert rep.tokens_per_sec == 123.0
    # and the registry itself carries the counts
    snap = stats.to_metrics().snapshot()
    assert snap["counters"]["engine/bt_uploads"] == 7
    assert snap["gauges"]["engine/slot_occupancy"] == pytest.approx(0.8)


# --------------------------------------------------- zero-overhead guards
def test_sim_zero_overhead_bit_identical(plan):
    kw = dict(n_steps=6, rollouts_per_step=32, eta=4, reward_cost_s=0.1)
    base = AsyncRLSimulator(plan, P, SimConfig(**kw)).run()
    traced = AsyncRLSimulator(plan, P, SimConfig(
        **kw, trace=Tracer(), metrics=MetricsRegistry())).run()
    assert base == traced                       # dataclass eq: every field


def test_pool_plans_bit_identical_with_and_without_trace():
    jobs = [JobSpec("a", PAPER_MODELS["1.5B"], P,
                    SchedulerConfig(tokens_per_step=2**18, stable_iters=3,
                                    max_iters=12, adapt_delta=False))]
    cluster = paper_heterogeneous(8, 8)
    p0 = schedule_pool(jobs, cluster)
    tr = Tracer()
    p1 = schedule_pool(jobs, cluster, trace=tr)
    assert p0.signature() == p1.signature()
    assert p0.owner == p1.owner
    spans = list(tr.spans("scheduler", "pool"))
    assert len(spans) == 1 and spans[0][0] == "schedule_pool"


@pytest.mark.slow
def test_engine_tokens_bit_identical_with_tracer():
    import jax
    from repro.data.tasks import MathTaskGenerator, Tokenizer
    from repro.models.api import ModelConfig, get_model
    from repro.rl.rollout import GenConfig
    from repro.rl.weight_sync import WeightStore
    from repro.serve import PagedEngine, ServeConfig
    tok = Tokenizer()
    tiny = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                       n_heads=4, n_kv_heads=2, d_ff=64,
                       vocab=tok.vocab_size, dtype="float32", remat=False)
    store = WeightStore()
    store.publish(get_model(tiny).init(jax.random.PRNGKey(0), tiny))
    tasks = MathTaskGenerator(seed=3).batch(3)
    gen = GenConfig(max_new_tokens=10, greedy=True, eos_id=-1)
    sv = ServeConfig(max_slots=3, max_len=96, page_size=8, prefill_chunk=8)
    r0, _ = PagedEngine(tiny, store, gen, sv, rng_seed=1).generate(tasks)
    tr = Tracer()
    r1, _ = PagedEngine(tiny, store, gen, sv, rng_seed=1,
                        tracer=tr).generate(tasks)
    assert [r.completion_ids for r in r0] == [r.completion_ids for r in r1]
    assert tr.n_events > 0 and tr.open_spans() == {}


# --------------------------------------------------------- conservation
@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 10**6))
def test_trace_matches_conservation_ledger(plan, seed):
    tr = Tracer()
    res = AsyncRLSimulator(plan, P, SimConfig(
        n_steps=5, rollouts_per_step=32, eta=4, reward_cost_s=0.1,
        seed=seed, trace=tr)).run()
    assert tr.open_spans() == {}
    ledger = tr.meta["ledger"]
    # every replica generate-span second is in the ledger, exactly
    busy = sum(dur for (_, _, dur, _) in tr.spans("replica"))
    assert busy == pytest.approx(ledger["gen_busy_s"], rel=1e-9)
    # train-span tokens reproduce the ledger throughput within the gate
    report = analyze_trace(tr.to_chrome())
    assert check_report(report, min_stages=2, max_tput_err=0.01) == []
    assert report["throughput"]["ledger_tps"] == pytest.approx(
        res.throughput_tps)


def test_analyzer_cli_gates(plan, tmp_path):
    tr = Tracer()
    AsyncRLSimulator(plan, P, SimConfig(
        n_steps=5, rollouts_per_step=32, eta=4, reward_cost_s=0.1,
        trace=tr)).run()
    p = tmp_path / "trace.json"
    tr.dump(str(p))
    assert analyze_main(["analyze", str(p), "--min-stages", "2"]) == 0
    # an impossible stage floor trips the gate
    assert analyze_main(["analyze", str(p), "--min-stages", "99"]) == 1


# ------------------------------------------------------- control plane
def test_control_plane_metrics_and_admission_latency():
    mx = MetricsRegistry()
    tr = Tracer()
    cp = ControlPlane(paper_heterogeneous(8, 8),
                      cfg=AdmissionConfig(price_on_submit=False),
                      tracer=tr, metrics=mx)
    spec = JobSpec("j", PAPER_MODELS["1.5B"], P,
                   SchedulerConfig(tokens_per_step=2**18, stable_iters=3,
                                   max_iters=12, adapt_delta=False))
    dec = cp.submit(spec, t=5.0)
    assert dec.action == "queue"
    # fabricate the commit: the plan placed the queued job at t=12
    pool = PoolPlan(jobs=(spec,), plans={}, owner={}, objective=0.0)
    assert cp.on_pool_commit(pool, t=12.0) == ["j"]
    snap = mx.snapshot()
    assert snap["counters"]["jobs/decisions/queue"] == 1.0
    h = snap["histograms"]["jobs/admission_latency_s"]
    assert h["count"] == 1 and h["sum"] == pytest.approx(7.0)
    kinds = {e[3] for e in tr._events if e[0] == "i"}
    assert {"submit", "admission:queue", "running"} <= kinds


def test_buffer_staleness_metrics():
    mx = MetricsRegistry()
    buf = RolloutBuffer(StalenessConfig(eta=2, rollouts_per_step=4),
                        metrics=mx)
    buf.launch(4)
    for _ in range(4):
        buf.push(Rollout([1], [2], np.zeros(1), version=buf.version,
                         group_id=0))
    buf.bump_version()                          # staleness becomes 1
    buf.pop_batch(4)
    snap = mx.snapshot()
    assert snap["counters"]["buffer/pushed"] == 4.0
    assert snap["counters"]["buffer/consumed"] == 4.0
    h = snap["histograms"]["buffer/staleness"]
    assert h["count"] == 4 and h["sum"] == pytest.approx(4.0)


# ------------------------------------------------------------- logging
def test_structured_logger_modes(capsys):
    obs_log.configure(json_logs=False, quiet=False)
    obs_log.info("hello", x=1)
    assert capsys.readouterr().out == "hello\n"
    obs_log.configure(json_logs=True, quiet=False)
    obs_log.info("hello", x=1)
    assert json.loads(capsys.readouterr().out) == {"msg": "hello", "x": 1}
    obs_log.configure(json_logs=False, quiet=True)
    obs_log.info("hello")
    assert capsys.readouterr().out == ""
    obs_log.configure()                         # reset to defaults
