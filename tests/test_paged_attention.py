"""Paged decode-attention kernel vs its jnp oracle and the dense kernel.

All kernel runs use interpret=True (the CPU contract); the same entry
point compiles on TPU.  The properties that matter for a paged cache:

  * ragged per-sequence lengths (the tail page is masked, never read);
  * arbitrary page *placement* — outputs are invariant to permuting the
    pool as long as block tables follow;
  * garbage in unused pool slots (stale pages, the null page) never
    leaks into any sequence's output;
  * agreement with the dense decode kernel on the densified cache.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.paged_attention.ops import paged_decode_attention
from repro.kernels.paged_attention.ref import paged_decode_attention_ref


def _tol(dtype):
    return dict(atol=5e-2, rtol=5e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


def _pool(B, H, Hkv, D, page, maxp, dtype, seed=0, shuffle=True):
    """Random pool + shuffled block tables + ragged lengths."""
    P = B * maxp + 1
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    kp = jax.random.normal(ks[1], (P, page, Hkv, D), dtype)
    vp = jax.random.normal(ks[2], (P, page, Hkv, D), dtype)
    rng = np.random.default_rng(seed)
    ids = rng.permutation(np.arange(1, P)) if shuffle \
        else np.arange(1, P)
    bt = jnp.asarray(ids[:B * maxp].reshape(B, maxp), jnp.int32)
    lens = jnp.asarray(rng.integers(1, maxp * page + 1, B), jnp.int32)
    return q, kp, vp, bt, lens


@pytest.mark.parametrize("B,H,Hkv,D,page,maxp", [
    (1, 2, 2, 8, 4, 2),
    (2, 4, 2, 16, 8, 4),
    (2, 8, 1, 64, 16, 3),        # MQA
    (3, 6, 3, 20, 8, 5),         # odd head dim → padding path
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 7])
def test_paged_attention_sweep(B, H, Hkv, D, page, maxp, dtype, window):
    q, kp, vp, bt, lens = _pool(B, H, Hkv, D, page, maxp, dtype,
                                seed=B * D + page)
    out = paged_decode_attention(q, kp, vp, bt, lens, window=window,
                                 interpret=True)
    ref = paged_decode_attention_ref(q, kp, vp, bt, lens, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_page_permutation_invariance():
    """Physical placement is irrelevant: permute the pool, remap the
    tables, outputs must match."""
    B, H, Hkv, D, page, maxp = 2, 4, 2, 16, 8, 3
    q, kp, vp, bt, lens = _pool(B, H, Hkv, D, page, maxp, jnp.float32,
                                seed=9, shuffle=False)
    base = paged_decode_attention(q, kp, vp, bt, lens, interpret=True)

    P = kp.shape[0]
    rng = np.random.default_rng(1)
    perm = np.concatenate([[0], 1 + rng.permutation(P - 1)])   # keep null
    inv = np.argsort(perm)              # page p moves to slot perm[p]
    kp2 = kp[jnp.asarray(inv)]          # so new slot i holds old page inv[i]
    vp2 = vp[jnp.asarray(inv)]
    bt2 = jnp.asarray(perm)[bt]         # tables follow the move
    np.testing.assert_allclose(
        np.asarray(paged_decode_attention(q, kp2, vp2, bt2, lens,
                                          interpret=True)),
        np.asarray(base), atol=1e-6, rtol=1e-6)


def test_garbage_pages_never_leak():
    """Unreferenced pool slots and masked tails hold huge garbage; every
    output must still match an oracle computed from clean data."""
    B, H, Hkv, D, page, maxp = 2, 4, 2, 16, 8, 3
    q, kp, vp, bt, lens = _pool(B, H, Hkv, D, page, maxp, jnp.float32,
                                seed=4)
    lens = jnp.asarray([3, page * maxp], jnp.int32)   # tiny + full
    ref = paged_decode_attention_ref(q, kp, vp, bt, lens)

    # poison the null page and every slot past each sequence's length
    kp_np, vp_np = np.array(kp), np.array(vp)
    kp_np[0], vp_np[0] = 1e6, -1e6
    slot = np.arange(maxp * page).reshape(maxp, page)
    for b in range(B):
        dead = slot >= int(lens[b])
        for ip in range(maxp):
            kp_np[int(bt[b, ip])][dead[ip]] = 1e6
            vp_np[int(bt[b, ip])][dead[ip]] = -1e6
    out = paged_decode_attention(q, jnp.asarray(kp_np), jnp.asarray(vp_np),
                                 bt, lens, interpret=True)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [None, 5])
def test_paged_matches_dense_decode_ref(window):
    """Densify the paged cache → the dense decode oracle must agree (the
    engine's two attention paths are the same math)."""
    B, H, Hkv, D, page, maxp = 3, 4, 2, 16, 4, 4
    q, kp, vp, bt, lens = _pool(B, H, Hkv, D, page, maxp, jnp.float32,
                                seed=2)
    C = page * maxp
    kd = kp[bt].reshape(B, C, Hkv, D)
    vd = vp[bt].reshape(B, C, Hkv, D)
    slot = jnp.broadcast_to(jnp.arange(C, dtype=jnp.int32), (B, C))
    k_pos = jnp.where(slot < lens[:, None], slot, -(2 ** 30))
    q_pos = lens - 1
    dense = decode_attention_ref(q, kd, vd, q_pos, k_pos, window=window)
    paged = paged_decode_attention(q, kp, vp, bt, lens, window=window,
                                   interpret=True)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(dense),
                               atol=2e-5, rtol=2e-5)


def test_length_one_and_stale_table_entries():
    """len=1 sequences attend to exactly one slot; table entries past the
    sequence's pages may be stale ids — clamped + masked, never read."""
    B, H, Hkv, D, page, maxp = 2, 2, 2, 8, 4, 3
    q, kp, vp, bt, lens = _pool(B, H, Hkv, D, page, maxp, jnp.float32,
                                seed=7)
    lens = jnp.asarray([1, 2], jnp.int32)
    bt = np.array(bt)
    bt[:, 1:] = 10 ** 6                     # absurd ids beyond page 0's need
    bt = jnp.asarray(bt)
    out = paged_decode_attention(q, kp, vp, bt, lens, interpret=True)
    ref = paged_decode_attention_ref(q, kp, vp,
                                     jnp.clip(bt, 0, kp.shape[0] - 1), lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)
