"""Crash-consistent recovery (ISSUE 10): checkpoint durability, the
snapshot/journal manager, controller-crash injection in both simulator
loops with bounded-loss gates, engine quiesce token-identity, the
monitor's snapshot-age detector, and the snapshot→restore→replay
property test."""
import copy
import json
import os

import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:
    from _prop import given, settings, st

from repro.ckpt.checkpoint import (CheckpointManager, latest_step,
                                   restore_checkpoint, save_checkpoint,
                                   sweep_tmp)
from repro.core.cluster import paper_heterogeneous
from repro.core.cost_model import LengthDistribution
from repro.core.model_spec import PAPER_MODELS
from repro.core.pool import JobSpec, schedule_pool
from repro.core.scheduler import SchedulerConfig, schedule
from repro.core.staleness import PoolStalenessRegistry, StalenessConfig
from repro.obs import HealthMonitor, MetricsRegistry, MonitorConfig
from repro.recovery import (RecoveryConfig, RecoveryError, RecoveryManager,
                            capture_buffers, capture_registry,
                            replan_for_restore, restore_buffers,
                            restore_registry, verify_restored)
from repro.rl.buffer import JobBuffers, Rollout
from repro.sim import (AsyncRLSimulator, ControllerCrash, MultiJobSimulator,
                       MultiSimConfig, SimConfig)

P = LengthDistribution(mean_len=1024, prompt_len=128)
SCHED_CFG = SchedulerConfig(tokens_per_step=2 ** 18, stable_iters=3,
                            max_iters=12, adapt_delta=False)
SIM = dict(n_steps=8, rollouts_per_step=32, eta=4, reward_cost_s=0.1)


@pytest.fixture(scope="module")
def plan():
    return schedule(PAPER_MODELS["1.5B"], paper_heterogeneous(16, 16), P,
                    SCHED_CFG)


def _pool_and_cluster():
    cluster = paper_heterogeneous(8, 24)
    cfg4 = SchedulerConfig(tokens_per_step=2 ** 18, stable_iters=3,
                           max_iters=12, adapt_delta=False,
                           staleness=StalenessConfig(eta=4))
    cfg2 = SchedulerConfig(tokens_per_step=2 ** 18, stable_iters=3,
                           max_iters=12, adapt_delta=False,
                           staleness=StalenessConfig(eta=2))
    jobs = [JobSpec("j1.5b", PAPER_MODELS["1.5B"], P, cfg4, weight=1.0),
            JobSpec("j7b", PAPER_MODELS["7B"], P, cfg2, weight=4.0)]
    return schedule_pool(jobs, cluster), cluster


@pytest.fixture(scope="module")
def pool_cluster():
    return _pool_and_cluster()


# ==================================================== checkpoint durability
def test_meta_present_and_parseable_in_every_retained_ckpt(tmp_path):
    for step in range(1, 6):
        save_checkpoint(tmp_path, step, {"params": np.arange(step),
                                         "version": step}, keep=3)
    kept = sorted(p for p in tmp_path.iterdir()
                  if p.name.startswith("step-"))
    assert len(kept) == 3                       # keep policy held
    for p in kept:
        with open(p / "META.json") as f:
            meta = json.load(f)                 # parseable, not truncated
        assert meta["step"] == int(p.name.split("-")[1])
        assert meta["keys"] == ["params", "version"]
    assert latest_step(tmp_path) == 5


def test_sweep_tmp_on_manager_init_and_after_save(tmp_path):
    # a save that died mid-write leaves its mkdtemp dir behind
    leak = tmp_path / "tmp-7-deadbeef"
    leak.mkdir(parents=True)
    (leak / "state.pkl").write_bytes(b"partial")
    CheckpointManager(tmp_path, every=1)
    assert not leak.exists(), "init did not sweep stale tmp dirs"

    leak2 = tmp_path / "tmp-9-cafebabe"
    leak2.mkdir()
    save_checkpoint(tmp_path, 1, {"x": 0}, keep=3)
    assert not leak2.exists(), "save did not sweep stale tmp dirs"
    assert (tmp_path / "step-00000001").exists()


def test_sweep_tmp_returns_removed_and_ignores_missing(tmp_path):
    assert sweep_tmp(tmp_path / "nope") == []
    (tmp_path / "tmp-1-x").mkdir()
    (tmp_path / "step-00000001").mkdir()
    removed = sweep_tmp(tmp_path)
    assert [p.name for p in removed] == ["tmp-1-x"]
    assert (tmp_path / "step-00000001").exists()


# ===================================================== RecoveryManager unit
def test_retry_with_backoff_then_success():
    m = RecoveryManager(RecoveryConfig(max_retries=4, backoff_s=0.1))
    sleeps = []
    m._sleep = sleeps.append
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("disk hiccup")
        return "ok"

    assert m._with_retry("write", flaky) == "ok"
    assert sleeps == [0.1, 0.2]                 # exponential backoff


def test_retry_exhaustion_raises_typed_error():
    m = RecoveryManager(RecoveryConfig(max_retries=3, backoff_s=0.01))
    m._sleep = lambda s: None

    def always_fails():
        raise OSError("full")

    with pytest.raises(RecoveryError, match="3 attempts"):
        m._with_retry("journal append", always_fails)


def test_config_rejects_cost_at_or_above_cadence():
    # a stop-the-world pause >= the cadence would starve the trainer:
    # each snapshot re-arms the pause before the wake event fires
    with pytest.raises(ValueError, match="snapshot_cost_s"):
        RecoveryConfig(interval_s=5.0, snapshot_cost_s=5.0)
    RecoveryConfig(interval_s=5.0, snapshot_cost_s=4.9)   # just below: fine


def test_latest_without_snapshot_raises():
    with pytest.raises(RecoveryError, match="no snapshot"):
        RecoveryManager().latest()


def test_file_mode_roundtrip_survives_process_death(tmp_path):
    d = str(tmp_path / "rec")
    m = RecoveryManager(RecoveryConfig(interval_s=5.0, directory=d))
    m.snapshot(10.0, {"steps": 3, "buffer": [1, 2]})
    m.journal({"k": "rollout", "rid": 7})
    m.journal({"k": "train", "rids": [7]})

    # a fresh manager on the same directory == a new process after a crash
    m2 = RecoveryManager(RecoveryConfig(interval_s=5.0, directory=d))
    t, state, entries = m2.latest()
    assert t == 10.0
    assert state == {"steps": 3, "buffer": [1, 2]}
    assert entries == [{"k": "rollout", "rid": 7}, {"k": "train",
                       "rids": [7]}]

    # a new snapshot truncates the journal durably
    m2.snapshot(20.0, {"steps": 4})
    m3 = RecoveryManager(RecoveryConfig(interval_s=5.0, directory=d))
    t, state, entries = m3.latest()
    assert (t, state, entries) == (20.0, {"steps": 4}, [])


def test_manager_age_and_stats():
    m = RecoveryManager(RecoveryConfig(interval_s=5.0))
    assert m.age(100.0) == float("inf")
    m.snapshot(10.0, {})
    assert m.age(13.5) == 3.5
    s = m.stats()
    assert s["n_snapshots"] == 1 and s["last_snapshot_t"] == 10.0


def test_snapshot_feeds_metrics_and_monitor():
    reg = MetricsRegistry()
    mon = HealthMonitor(MonitorConfig(snapshot_interval_s=5.0))
    m = RecoveryManager(RecoveryConfig(interval_s=5.0), metrics=reg,
                        monitor=mon)
    m.snapshot(10.0, {})
    assert mon._last_snapshot_t == 10.0
    snap = reg.snapshot()
    assert snap["gauges"]["ckpt/snapshot_age_s"] == 0.0
    m.observe_age(14.0)
    assert reg.snapshot()["gauges"]["ckpt/snapshot_age_s"] == 4.0


# ================================================ monitor snapshot-age alarm
def test_monitor_snapshot_age_detector():
    mon = HealthMonitor(MonitorConfig(snapshot_interval_s=10.0,
                                      cooldown_s=1.0))
    assert mon.poll(50.0) == []                 # no snapshot regime yet
    mon.on_snapshot(0.0)
    assert mon.poll(8.0) == []                  # within cadence
    warn = mon.poll(15.0)
    assert [a.detector for a in warn] == ["snapshot"]
    assert warn[0].severity == "warn"
    crit = mon.poll(25.0)                       # age > 2× interval
    assert crit and crit[0].severity == "critical"
    mon.on_snapshot(30.0)
    assert mon.poll(35.0) == []                 # fresh snapshot clears it


def test_monitor_snapshot_detector_disabled_by_default():
    mon = HealthMonitor()                       # snapshot_interval_s == 0
    mon.on_snapshot(0.0)
    assert mon.poll(1e6) == []


# =========================================== single-job simulator crash gates
def test_single_job_bit_identical_with_recovery_attached(plan):
    off = AsyncRLSimulator(plan, P, SimConfig(**SIM, seed=3)).run()
    mgr = RecoveryManager(RecoveryConfig(interval_s=5.0))
    on = AsyncRLSimulator(plan, P, SimConfig(**SIM, seed=3,
                                             recovery=mgr)).run()
    assert on == off                            # dataclass equality: all of it
    assert mgr.n_snapshots > 1


def test_single_job_snapshot_cost_pauses_but_completes(plan):
    """A nonzero ``snapshot_cost_s`` stalls the trainer for the pause but
    the run still finishes (the ``trainer_wake`` event re-runs the probe
    once the pause ends — without it a fully capacity-paused queue would
    spin on snapshots forever)."""
    off = AsyncRLSimulator(plan, P, SimConfig(**SIM, seed=3)).run()
    mgr = RecoveryManager(RecoveryConfig(interval_s=5.0,
                                         snapshot_cost_s=2.0))
    on = AsyncRLSimulator(plan, P, SimConfig(**SIM, seed=3,
                                             recovery=mgr)).run()
    assert on.steps == SIM["n_steps"]
    assert on.wall_time_s >= off.wall_time_s    # pauses are never free speedups
    assert on.tokens_consumed == off.tokens_consumed


def test_single_job_crash_requires_manager(plan):
    with pytest.raises(ValueError, match="recovery"):
        AsyncRLSimulator(plan, P, SimConfig(
            **SIM, seed=3, crashes=[ControllerCrash(5.0)])).run()


@pytest.mark.parametrize("t_crash", [3.0, 7.5, 12.0, 20.0])
def test_single_job_crash_bounded_loss(plan, t_crash):
    """Gates (a)-(c): with the journal on, no consumed progress is lost,
    the run still completes, invariants (η, conservation, capacity) are
    re-checked at every subsequent event, and the snapshot the restore
    used was at most one interval old."""
    mgr = RecoveryManager(RecoveryConfig(interval_s=5.0,
                                         restore_latency_s=2.0))
    r = AsyncRLSimulator(plan, P, SimConfig(
        **SIM, seed=3, recovery=mgr, check_invariants=True,
        crashes=[ControllerCrash(t_crash)])).run()
    assert r.steps == SIM["n_steps"]
    [rv] = r.recoveries
    assert rv.lost_consumed == 0                # journal: exactly-once replay
    assert rv.snapshot_age_s <= mgr.cfg.interval_s + 1e-9
    assert rv.mttr_s == 2.0
    assert rv.t_resume == t_crash + 2.0
    assert rv.lost_inflight >= 0


def test_single_job_double_crash(plan):
    mgr = RecoveryManager(RecoveryConfig(interval_s=5.0,
                                         restore_latency_s=2.0))
    r = AsyncRLSimulator(plan, P, SimConfig(
        **SIM, seed=3, recovery=mgr, check_invariants=True,
        crashes=[ControllerCrash(8.0), ControllerCrash(16.0)])).run()
    assert r.steps == SIM["n_steps"]
    assert len(r.recoveries) == 2
    assert all(rv.lost_consumed == 0 for rv in r.recoveries)


def test_single_job_crash_journal_off_loss_bounded_by_interval(plan):
    """Gate (a) without the journal: loss is bounded by one snapshot
    interval — everything consumed before the last snapshot survives."""
    mgr = RecoveryManager(RecoveryConfig(interval_s=5.0,
                                         restore_latency_s=2.0,
                                         journal=False))
    r = AsyncRLSimulator(plan, P, SimConfig(
        **SIM, seed=3, recovery=mgr, check_invariants=True,
        crashes=[ControllerCrash(12.0)])).run()
    assert r.steps == SIM["n_steps"]            # lost work is re-done
    [rv] = r.recoveries
    assert rv.snapshot_age_s <= mgr.cfg.interval_s + 1e-9
    assert rv.journal_replayed == 0
    assert rv.consumed_after <= rv.consumed_before


# ============================================ multi-job simulator crash gates
def test_multi_job_bit_identical_with_recovery_attached(pool_cluster):
    pool, _ = pool_cluster
    base = dict(n_steps=6, rollouts_per_step=32, check_invariants=True)
    off = MultiJobSimulator(pool, MultiSimConfig(**base)).run()
    mgr = RecoveryManager(RecoveryConfig(interval_s=5.0))
    on = MultiJobSimulator(pool, MultiSimConfig(**base,
                                                recovery=mgr)).run()
    assert on == off
    assert mgr.n_snapshots > 1


def test_multi_job_crash_requires_manager(pool_cluster):
    pool, _ = pool_cluster
    with pytest.raises(ValueError, match="recovery"):
        MultiJobSimulator(pool, MultiSimConfig(
            n_steps=2, rollouts_per_step=32,
            crashes=[ControllerCrash(3.0)])).run()


@pytest.mark.parametrize("t_crash", [4.0, 11.0, 17.0])
def test_multi_job_crash_bounded_loss(pool_cluster, t_crash):
    """Gates (a)-(c) pool-wide: every job completes, no consumed progress
    lost, η + per-job conservation + the device-ledger partition are
    proved inside the restore (a violation raises) and re-checked by
    check_invariants for the rest of the run."""
    pool, _ = pool_cluster
    mgr = RecoveryManager(RecoveryConfig(interval_s=5.0,
                                         restore_latency_s=2.0))
    r = MultiJobSimulator(pool, MultiSimConfig(
        n_steps=6, rollouts_per_step=32, check_invariants=True,
        recovery=mgr, crashes=[ControllerCrash(t_crash)])).run()
    assert all(j.steps == 6 for j in r.per_job.values())
    [rv] = r.recoveries
    assert rv.lost_consumed == 0
    assert rv.snapshot_age_s <= mgr.cfg.interval_s + 1e-9
    assert rv.mttr_s == 2.0
    for j in r.per_job.values():                # conservation at the end
        assert j.rollouts_launched == (j.rollouts_trained + j.dropped
                                       + j.rollouts_in_buffer
                                       + j.rollouts_generating)


# ============================================================ changed pool
def test_replan_for_restore_excludes_dead_devices(pool_cluster):
    import dataclasses
    pool, cluster = pool_cluster
    dead = sorted(pool.job_devices("j1.5b"))[:2]
    new = replan_for_restore(pool, cluster, dead_devices=dead)
    assert not set(dead) & set(new.owner)       # nobody owns a dead device
    surviving = dataclasses.replace(
        cluster, devices=[d for d in cluster.devices
                          if d.index not in set(dead)])
    new.assert_partition(surviving)


# ===================================================== engine quiesce gates
def _tiny_engine(greedy):
    import jax
    from repro.data.tasks import MathTaskGenerator, Tokenizer
    from repro.models.api import ModelConfig, get_model
    from repro.rl.rollout import GenConfig
    from repro.rl.weight_sync import WeightStore
    from repro.serve import PagedEngine, ServeConfig

    tok = Tokenizer()
    tiny = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                       n_heads=4, n_kv_heads=2, d_ff=64,
                       vocab=tok.vocab_size, dtype="float32", remat=False)
    model = get_model(tiny)
    store = WeightStore()
    store.publish(model.init(jax.random.PRNGKey(0), tiny))
    gen = GenConfig(max_new_tokens=12, greedy=greedy)
    # small prefill chunks so prompts take several steps to prefill —
    # quiesce must actually find mid-prefill requests to drain
    sc = ServeConfig(max_slots=4, max_len=96, prefill_chunk=2)
    eng = PagedEngine(tiny, store, gen, sc, rng_seed=1)
    tasks = MathTaskGenerator(seed=0).batch(6)
    return eng, tasks


def test_quiesce_leaves_no_half_prefilled_request():
    eng, tasks = _tiny_engine(greedy=True)
    eng.submit(tasks)
    eng.step()                                  # admit + begin prefilling
    assert any(r.state in ("PREFILL", "FORK")
               for r in eng._active.values())
    steps = eng.quiesce()
    assert steps > 0
    assert all(r.state == "DECODE" for r in eng._active.values())
    assert eng._queue                            # unadmitted work stays queued


def test_quiesce_resumed_run_token_identical():
    """A run interrupted by quiesce (the drain-to-checkpoint boundary)
    produces exactly the tokens of an uninterrupted run."""
    eng_a, tasks = _tiny_engine(greedy=True)
    eng_a.submit(tasks)
    eng_a.drain()
    plain, _ = eng_a.collect()

    eng_b, tasks = _tiny_engine(greedy=True)
    eng_b.submit(tasks)
    eng_b.step()
    eng_b.quiesce()                             # checkpointable boundary
    eng_b.step()
    eng_b.quiesce()                             # and again mid-run
    eng_b.drain()
    quiesced, _ = eng_b.collect()

    assert [r.completion_ids for r in plain] == \
        [r.completion_ids for r in quiesced]


# =================================== property: snapshot → restore → replay
_OPS = ["push_a", "push_b", "gen_a", "finish_a", "pop_a", "pop_b",
        "bump_a", "bump_b", "handoff_ab", "handoff_ba", "swap_a",
        "snap", "crash"]


def _mk_state():
    bufs, reg = JobBuffers(), PoolStalenessRegistry()
    model = {}
    for name, eta in (("a", 2), ("b", 1)):
        cfg = StalenessConfig(eta=eta, rollouts_per_step=4)
        bufs.add_job(name, cfg)
        reg.add_job(name, cfg)
        model[name] = {"launched": 0, "consumed": 0, "dropped": 0,
                       "generating": 0}
    return bufs, reg, model


def _capture(bufs, reg, model):
    return {"bufs": capture_buffers(bufs), "reg": capture_registry(reg),
            "model": copy.deepcopy(model)}


def _rollout(version):
    return Rollout(prompt_ids=[1, 2], completion_ids=[3],
                   behavior_logp=np.zeros(1, np.float32),
                   version=version, group_id=0)


def _check_conservation(bufs, reg, model):
    counters = {}
    for name in bufs.jobs():
        b, m = bufs[name], model[name]
        assert b.ctl.in_flight == len(b._items) + m["generating"], name
        counters[name] = {"launched": m["launched"],
                          "consumed": m["consumed"],
                          "dropped": m["dropped"] + b.dropped,
                          "in_flight": b.ctl.in_flight}
    verify_restored(registry=reg, buffers=bufs, counters=counters)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.sampled_from(_OPS), min_size=1, max_size=60))
def test_snapshot_restore_replay_property(ops):
    """Under arbitrary interleavings of push/pop/bump/handoff/swap/crash,
    a restore from the last snapshot (i) passes ``verify_restored``,
    (ii) is idempotent (restoring twice gives the identical capture),
    and (iii) keeps per-job conservation exact after every op."""
    bufs, reg, model = _mk_state()
    snap = _capture(bufs, reg, model)

    def buf_dropped():                          # bump_version-evicted count
        return {n: bufs[n].dropped for n in bufs.jobs()}

    for op in ops:
        if op in ("push_a", "push_b"):
            name = op[-1]
            b = bufs[name]
            if b.can_launch(1):
                b.launch(1)
                reg.controller(name).launch(1)
                b.push(_rollout(b.ctl.version))
                model[name]["launched"] += 1
        elif op == "gen_a":                     # launched, still generating
            if bufs["a"].can_launch(1):
                bufs["a"].launch(1)
                reg.controller("a").launch(1)
                model["a"]["launched"] += 1
                model["a"]["generating"] += 1
        elif op == "finish_a":                  # generation completes
            if model["a"]["generating"] > 0:
                bufs["a"].push(_rollout(bufs["a"].ctl.version))
                model["a"]["generating"] -= 1
        elif op in ("pop_a", "pop_b"):
            name = op[-1]
            b = bufs[name]
            if b.ready(2):
                batch = b.pop_batch(2)
                reg.controller(name).consume([r.version for r in batch])
                model[name]["consumed"] += 2
        elif op in ("bump_a", "bump_b"):
            name = op[-1]
            before = bufs[name].dropped
            bufs[name].bump_version()
            evicted = bufs[name].dropped - before
            reg.controller(name).bump_version()
            if evicted:
                reg.controller(name).drop(evicted)
        elif op in ("handoff_ab", "handoff_ba"):
            src, dst = op[-2], op[-1]
            bufs.on_device_handoff(src, dst)
            reg.record_handoff(src, dst)
        elif op == "swap_a":
            bufs["a"].on_plan_swap()
        elif op == "snap":
            snap = _capture(bufs, reg, model)
        elif op == "crash":
            bufs = restore_buffers(snap["bufs"])
            reg = restore_registry(snap["reg"])
            model = copy.deepcopy(snap["model"])
            # idempotence: a second restore from the same capture is
            # indistinguishable from the first
            again = restore_buffers(snap["bufs"])
            assert capture_buffers(again) == capture_buffers(bufs)
            assert capture_registry(restore_registry(snap["reg"])) == \
                capture_registry(reg)
        _check_conservation(bufs, reg, model)
