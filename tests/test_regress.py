"""Perf-regression harness + histogram quantiles: metric extraction from
BENCH payloads, direction-aware tolerance checks, the regress CLI's exit
codes, and the interpolated p50/p95/p99 surfaced through snapshots."""
import json

import pytest

from repro.obs import MetricsRegistry, hist_frac_ge, hist_quantile
from repro.obs.regress import (classify_direction, compare_dirs,
                               compare_metrics, extract_metrics,
                               format_report, is_wallclock)
from repro.obs.regress import main as regress_main


# ================================================================ quantiles
def test_histogram_quantiles_interpolated():
    mx = MetricsRegistry()
    h = mx.histogram("lat", buckets=(1.0, 2.0, 4.0, 8.0))
    for v in (0.5, 1.5, 2.5, 3.0, 3.5, 6.0):
        h.observe(v)
    assert h.quantile(0.0) <= h.quantile(0.5) <= h.quantile(0.99)
    # 4/6 samples ≤ 4.0 → p50 lands inside the (2, 4] bucket
    assert 2.0 < h.quantile(0.5) <= 4.0
    assert 4.0 < h.quantile(0.99) <= 8.0
    snap = mx.snapshot()["histograms"]["lat"]
    for k in ("p50", "p95", "p99"):
        assert k in snap
    assert snap["p50"] == pytest.approx(h.quantile(0.5))


def test_histogram_quantile_edge_cases():
    mx = MetricsRegistry()
    h = mx.histogram("x", buckets=(1.0, 2.0))
    assert h.quantile(0.5) == 0.0           # empty histogram
    for _ in range(4):
        h.observe(100.0)                    # all overflow
    # overflow bucket has no finite upper edge: conservative floor at the
    # last finite bound rather than an invented extrapolation
    assert h.quantile(0.5) == 2.0
    assert h.frac_ge(1.5) == pytest.approx(1.0)


def test_hist_frac_ge_interpolates():
    mx = MetricsRegistry()
    h = mx.histogram("s", buckets=(2.0, 4.0))
    for _ in range(10):
        h.observe(3.0)                      # all inside (2, 4]
    snap = mx.snapshot()["histograms"]["s"]
    assert hist_frac_ge(snap, 3.0) == pytest.approx(0.5)
    assert hist_frac_ge(snap, 2.0) == pytest.approx(1.0)
    assert hist_frac_ge(snap, 4.0) == pytest.approx(0.0)
    assert hist_quantile(snap, 0.5) == pytest.approx(3.0)


def test_snapshot_delta_recomputes_quantiles():
    mx = MetricsRegistry()
    h = mx.histogram("d", buckets=(1.0, 2.0, 4.0))
    h.observe(0.5)
    s0 = mx.snapshot()
    for _ in range(8):
        h.observe(3.0)
    from repro.obs import snapshot_delta
    d = snapshot_delta(mx.snapshot(), s0)["histograms"]["d"]
    assert d["count"] == 8
    # the delta's quantiles describe only the new observations
    assert 2.0 < d["p50"] <= 4.0


# ============================================================== extraction
PAYLOAD = {
    "name": "fig_demo",
    "rows": ["alloc,120,throughput=42608 tok/s ratio=1.16x",
             "swap,15,stall_s=0.35"],
    "token_identical": True,
    "g_eff": 0.87,
    "steps": 12,
}


def test_extract_metrics_from_rows_and_fields():
    m = extract_metrics(PAYLOAD)
    assert m["alloc/throughput"] == pytest.approx(42608.0)
    assert m["alloc/ratio"] == pytest.approx(1.16)
    assert m["swap/stall_s"] == pytest.approx(0.35)
    assert m["token_identical"] == 1.0      # bools are 0/1 metrics
    assert m["g_eff"] == pytest.approx(0.87)
    assert "name" not in m


def test_direction_classification():
    assert classify_direction("alloc/throughput") == "higher"
    assert classify_direction("e2e/tput") == "higher"
    assert classify_direction("hit_rate") == "higher"
    assert classify_direction("token_identical") == "higher"
    assert classify_direction("swap/stall_s") == "lower"
    assert classify_direction("p99/latency_s") == "lower"
    assert classify_direction("buffer/dropped") == "lower"
    assert classify_direction("mystery_number") == "both"
    # machine-dependent wall-clock is skipped by default
    assert is_wallclock("alloc/us")
    assert is_wallclock("sched/time_us")
    assert is_wallclock("table5/ours")
    assert not is_wallclock("alloc/throughput")


def test_compare_metrics_direction_aware():
    # checks come back sorted by metric: latency, other, throughput
    base = {"a/throughput": 100.0, "a/latency": 1.0, "a/other": 5.0}
    # throughput up + latency down: improvements, not regressions
    up = compare_metrics(base, {"a/throughput": 120.0, "a/latency": 0.5,
                                "a/other": 5.0}, tol=0.05)
    assert [c["status"] for c in up] == ["improved", "ok", "improved"]
    # throughput down / latency up beyond tolerance: regressions
    down = compare_metrics(base, {"a/throughput": 80.0, "a/latency": 2.0,
                                  "a/other": 5.0}, tol=0.05)
    assert [c["status"] for c in down] == ["regressed", "ok", "regressed"]
    # inside the tolerance band: ok (a/other is two-sided, 4% drift ok)
    ok = compare_metrics(base, {"a/throughput": 97.0, "a/latency": 1.04,
                                "a/other": 5.2}, tol=0.05)
    assert [c["status"] for c in ok] == ["ok", "ok", "ok"]
    missing = compare_metrics(base, {"a/throughput": 100.0}, tol=0.05)
    assert {c["status"] for c in missing} == {"ok", "missing"}
    # stall_s is a wall-clock metric: skipped, never regressed
    wc = compare_metrics({"a/stall_s": 1.0}, {"a/stall_s": 9.0}, tol=0.05)
    assert [c["status"] for c in wc] == ["skipped"]
    wc = compare_metrics({"a/stall_s": 1.0}, {"a/stall_s": 9.0}, tol=0.05,
                         include_wallclock=True)
    assert [c["status"] for c in wc] == ["regressed"]


# ============================================================ compare_dirs
def _write_payload(dirpath, payload):
    p = dirpath / f"BENCH_{payload['name']}.json"
    p.write_text(json.dumps(payload))
    return p


def test_compare_dirs_pass_and_fail(tmp_path):
    basedir = tmp_path / "base"
    rundir = tmp_path / "run"
    basedir.mkdir(), rundir.mkdir()
    _write_payload(basedir, PAYLOAD)
    _write_payload(rundir, PAYLOAD)         # identical → pass
    rep = compare_dirs(str(basedir), str(rundir))
    assert rep["ok"] and rep["n_regressions"] == 0
    assert rep["n_checks"] > 0
    assert "PASS" in format_report(rep)

    bad = json.loads(json.dumps(PAYLOAD))   # degrade throughput 40%
    bad["rows"][0] = "alloc,120,throughput=25000 tok/s ratio=1.16x"
    bad["token_identical"] = False          # and break an invariant bool
    _write_payload(rundir, bad)
    rep = compare_dirs(str(basedir), str(rundir))
    assert not rep["ok"]
    failed = {c["metric"] for p in rep["payloads"] for c in p["checks"]
              if c["status"] == "regressed"}
    assert failed == {"alloc/throughput", "token_identical"}
    assert "REGRESSION" in format_report(rep)


def test_compare_dirs_missing_payload_strict(tmp_path):
    basedir = tmp_path / "base"
    rundir = tmp_path / "run"
    basedir.mkdir(), rundir.mkdir()
    _write_payload(basedir, PAYLOAD)        # baseline exists, run empty
    rep = compare_dirs(str(basedir), str(rundir))
    assert rep["ok"]                        # lenient: subset runs pass
    assert rep["missing_payloads"] == ["fig_demo"]
    strict = compare_dirs(str(basedir), str(rundir), strict=True)
    assert not strict["ok"]


def test_wallclock_skipped_unless_requested(tmp_path):
    basedir = tmp_path / "base"
    rundir = tmp_path / "run"
    basedir.mkdir(), rundir.mkdir()
    p = {"name": "t", "rows": ["sched,100,ours=2.1"], "wall_s": 9.0}
    _write_payload(basedir, p)
    slow = {"name": "t", "rows": ["sched,900,ours=8.4"], "wall_s": 90.0}
    _write_payload(rundir, slow)
    rep = compare_dirs(str(basedir), str(rundir))    # 10× slower wall: pass
    assert rep["ok"]
    rep = compare_dirs(str(basedir), str(rundir), include_wallclock=True)
    assert not rep["ok"]


# ==================================================================== CLI
def test_regress_cli_exit_codes(tmp_path, capsys):
    basedir = tmp_path / "base"
    rundir = tmp_path / "run"
    basedir.mkdir(), rundir.mkdir()
    _write_payload(basedir, PAYLOAD)
    _write_payload(rundir, PAYLOAD)
    assert regress_main(["--baselines", str(basedir),
                         "--run", str(rundir)]) == 0
    bad = json.loads(json.dumps(PAYLOAD))
    bad["g_eff"] = 0.4                      # −54%, way past tolerance
    _write_payload(rundir, bad)
    report_path = tmp_path / "report.json"
    capsys.readouterr()                     # drop the text report above
    assert regress_main(["--baselines", str(basedir), "--run", str(rundir),
                         "--json", "--report", str(report_path)]) == 2
    out = json.loads(capsys.readouterr().out)
    assert not out["ok"]
    saved = json.loads(report_path.read_text())
    assert saved["n_regressions"] >= 1
    # a generous tolerance band waves the same delta through
    assert regress_main(["--baselines", str(basedir), "--run", str(rundir),
                         "--tol", "0.9"]) == 0
    # missing baselines dir is an error, not a silent pass
    assert regress_main(["--baselines", str(tmp_path / "nope"),
                         "--run", str(rundir)]) == 2


def test_regress_module_dispatch():
    """python -m repro.obs regress … routes to the regress CLI."""
    from repro.obs.__main__ import _dispatch
    assert _dispatch(["regress", "--baselines", "/nonexistent-xyz",
                      "--run", "."]) == 2


# ------------------------------------------------- analyze --metrics PATH
def test_summarize_metrics_roundtrip(tmp_path):
    from repro.obs.analyze import summarize_metrics
    mx = MetricsRegistry()
    mx.counter("c").inc(3)
    mx.gauge("g").set(7.0)
    h = mx.histogram("h", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.5, 3.0):
        h.observe(v)
    path = tmp_path / "metrics.json"
    mx.to_json(str(path))
    snap = json.loads(path.read_text())
    rep = summarize_metrics(snap)
    assert rep["counters"]["c"] == 3
    assert rep["gauges"]["g"] == 7.0
    assert rep["histograms"]["h"]["count"] == 3
    assert rep["histograms"]["h"]["p50"] == pytest.approx(
        hist_quantile(snap["histograms"]["h"], 0.5))
