"""RL runtime: buffer, weight sync, rollout engine, end-to-end trainer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.staleness import StalenessConfig
from repro.data.tasks import MathTaskGenerator, Tokenizer
from repro.models.api import ModelConfig
from repro.rl.buffer import Rollout, RolloutBuffer
from repro.rl.rollout import GenConfig, RolloutEngine
from repro.rl.weight_sync import (WeightStore, dequantize_int8,
                                  quantize_int8, tree_bytes)

TOK = Tokenizer()
TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                   n_heads=4, n_kv_heads=2, d_ff=64, vocab=TOK.vocab_size,
                   dtype="float32", remat=False)


def _rollout(version, gid=0):
    return Rollout(prompt_ids=[1, 5, 6], completion_ids=[7, 8, 2],
                   behavior_logp=np.zeros(3, np.float32), version=version,
                   group_id=gid)


def test_buffer_admission_and_eviction():
    buf = RolloutBuffer(StalenessConfig(eta=1, rollouts_per_step=2))
    buf.launch(4)
    for _ in range(4):
        buf.push(_rollout(version=0))
    buf.bump_version()                # version 1, lag 1 → still admissible
    assert len(buf) == 4
    batch = buf.pop_batch(2)
    assert all(r.version == 0 for r in batch)
    buf.bump_version()                # version 2, lag 2 > η → evict rest
    assert len(buf) == 0
    assert buf.dropped == 2


def test_buffer_capacity_enforced():
    buf = RolloutBuffer(StalenessConfig(eta=0, rollouts_per_step=2))
    assert buf.can_launch(2)
    buf.launch(2)
    assert not buf.can_launch(1)
    with pytest.raises(RuntimeError):
        buf.launch(1)


def test_int8_quantization_roundtrip_bound():
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (64, 64)),
            "b": jnp.linspace(-3, 3, 17)}
    q, s = quantize_int8(tree)
    back = dequantize_int8(q, s, jnp.float32)
    for k in tree:
        err = float(jnp.max(jnp.abs(back[k] - tree[k])))
        scale = float(jnp.max(jnp.abs(tree[k]))) / 127.0
        assert err <= scale * 0.75 + 1e-6     # ≤ half a quantization step


def test_weight_store_versions_and_payload():
    store = WeightStore(quantize=True)
    p1 = {"w": jnp.ones((8, 8))}
    v1 = store.publish(p1)
    p2 = {"w": 2.0 * jnp.ones((8, 8))}
    v2 = store.publish(p2)
    assert v2 == v1 + 1
    got, v = store.fetch()
    assert v == v2
    np.testing.assert_allclose(np.asarray(got["w"], np.float32), 2.0,
                               atol=0.05)
    # int8 payload ≈ 1 byte/elem vs 4 for fp32
    assert store.payload_bytes(p1) < tree_bytes(p1) / 3


def test_rollout_engine_generates_and_swaps_weights():
    store = WeightStore()
    from repro.models.api import get_model
    model = get_model(TINY)
    params = model.init(jax.random.PRNGKey(0), TINY)
    store.publish(params)
    eng = RolloutEngine(TINY, store,
                        GenConfig(max_new_tokens=24, segment=6))
    gen = MathTaskGenerator(seed=1)
    tasks = gen.batch(3)
    # publish a new version mid-call? engine checks at segment boundaries —
    # publish BEFORE so a swap is guaranteed at the first boundary
    store.publish(params)
    rollouts, metrics = eng.generate(tasks)
    assert len(rollouts) == 3
    for r in rollouts:
        assert 1 <= len(r.completion_ids) <= 24
        assert len(r.behavior_logp) == len(r.completion_ids)
        assert r.version >= 1
    assert metrics["mean_len"] > 0


def test_async_trainer_three_steps_staleness_bounded():
    from repro.rl.async_trainer import AsyncGRPOTrainer, TrainerConfig
    from repro.optim.adamw import AdamWConfig
    tc = TrainerConfig(total_steps=3, group_size=2, prompts_per_step=2,
                       seq_len=96,
                       staleness=StalenessConfig(eta=1, rollouts_per_step=4),
                       opt=AdamWConfig(lr=1e-4))
    tr = AsyncGRPOTrainer(TINY, tc)
    hist = tr.run(verbose=False)
    assert len(hist) == 3
    assert all(np.isfinite(h["loss"]) for h in hist)
    assert max(h["max_staleness"] for h in hist) <= 1
