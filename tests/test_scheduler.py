"""Core scheduler tests: Eq. 1-3, Algorithm 1, Table-5 baselines."""
import math

import pytest

from repro.core.cluster import (Cluster, paper_heterogeneous,
                                paper_homogeneous_h20,
                                paper_homogeneous_h800)
from repro.core.cost_model import (LengthDistribution, ReplicaConfig,
                                   TrainPlan, StageSpec, per_token_costs,
                                   replica_throughput, train_step_cost,
                                   weight_sync_cost)
from repro.core.constrained_search import constrained_search, exhaustive_search
from repro.core.graph_partition import (compute_fraction, eq3_objective,
                                        partition, partition_exhaustive)
from repro.core.milp import solve_rollout_milp, solve_rollout_milp_bisection
from repro.core.model_spec import PAPER_MODELS
from repro.core.scheduler import (SchedulerConfig, schedule,
                                  schedule_uniform)

SPEC = PAPER_MODELS["1.5B"]
P = LengthDistribution(mean_len=2048, prompt_len=256)
# the paper's operating point: long chain-of-thought rollouts (the serving
# engine efficiencies are calibrated against Table 1 at this regime)
P_LONG = LengthDistribution(mean_len=12288, prompt_len=512, max_len=32768)


def test_cluster_topology():
    c = paper_heterogeneous(8, 8)
    assert len(c) == 16
    h800 = c.devices_of_type("H800")
    h20 = c.devices_of_type("H20")
    assert len(h800) == len(h20) == 8
    # intra-node NVLink > inter-node > cross-type
    same_node = c.link_bw(h800[0], h800[1])
    cross = c.link_bw(h800[0], h20[0])
    assert same_node > cross
    assert cross == pytest.approx(1.5e9)


def test_train_cost_scales_down_with_devices():
    small = TrainPlan(stages=(StageSpec("H800", dp=1, tp=8, n_layers=28),))
    big = TrainPlan(stages=(StageSpec("H800", dp=4, tp=8, n_layers=28),))
    c1 = train_step_cost(SPEC, small, tokens_per_step=1e6)
    c2 = train_step_cost(SPEC, big, tokens_per_step=1e6)
    assert c2.total < c1.total


def test_replica_throughput_memory_bound():
    rc = replica_throughput(SPEC, ReplicaConfig("H20", (1,)), P)
    assert rc.feasible and rc.tokens_per_sec > 0
    # the paper's claim is COST efficiency at the long-CoT operating point:
    # H20 generates more tokens per dollar than H800 (absolute tps can favor
    # H800 at short context — Observation 1's nuance)
    rc_l = replica_throughput(SPEC, ReplicaConfig("H20", (1,)), P_LONG)
    rc800 = replica_throughput(SPEC, ReplicaConfig("H800", (1,)), P_LONG)
    assert rc_l.tokens_per_sec / 1.85 > rc800.tokens_per_sec / 5.28


def test_per_token_costs_reproduce_table1_direction():
    """Table 1: H20 cheaper per inference token; H800 cheaper per training
    token — the paper's Observation 1/2."""
    for name in ("1.5B", "7B", "14B"):
        spec = PAPER_MODELS[name]
        h800_inf, h800_tr = per_token_costs(spec, __import__(
            "repro.core.cluster", fromlist=["H800"]).H800, P_LONG)
        h20_inf, h20_tr = per_token_costs(spec, __import__(
            "repro.core.cluster", fromlist=["H20"]).H20, P_LONG)
        assert h20_inf < h800_inf, name
        assert h800_tr < h20_tr, name


def test_milp_respects_device_budget():
    c = paper_heterogeneous(8, 8)
    res = solve_rollout_milp(SPEC, c.devices, P, total_rollouts=512)
    used = {}
    for a in res.plan.assignments:
        used[a.config.profile_name] = used.get(a.config.profile_name, 0) \
            + a.count * a.config.n_devices
    counts = c.type_counts
    for t, n in used.items():
        assert n <= counts[t]
    # workloads sum to B
    assert sum(a.workload for a in res.plan.assignments) == pytest.approx(512)


def test_milp_bisection_matches_fast_path():
    c = paper_homogeneous_h20(8)
    fast = solve_rollout_milp(SPEC, c.devices, P, total_rollouts=256)
    slow = solve_rollout_milp_bisection(SPEC, c.devices, P,
                                        total_rollouts=256)
    assert slow.plan.makespan == pytest.approx(fast.plan.makespan, rel=0.05)


def test_constrained_search_same_type_constraint():
    c = paper_heterogeneous(8, 8)
    plan, cost = constrained_search(SPEC, c, c.devices,
                                    tokens_per_step=2**20)
    assert plan is not None and cost.feasible
    for st in plan.stages:   # TP/DP blocks homogeneous by construction
        assert st.profile_name in ("H800", "H20")


def test_graph_partition_eq3_and_gamma():
    c = paper_heterogeneous(8, 8)
    part = partition(c, 0.3, 0.9)
    assert part is not None
    g = compute_fraction(c, part.train_devices)
    assert 0.3 - 1e-9 <= g <= 0.9 + 1e-9
    # exact enumeration beats or equals any other γ-feasible bipartition
    brute = partition_exhaustive(c, 0.3, 0.9)
    assert part.objective >= brute.objective - 1e-9


def test_partition_prefers_high_hbm_for_inference():
    c = paper_heterogeneous(8, 8)
    part = partition(c, 0.5, 0.95)
    infer_types = {d.type_name for d in part.infer_devices}
    assert "H20" in infer_types   # 4TB/s HBM pool goes to rollout


def test_schedule_end_to_end_and_ci_ge_ct():
    c = paper_heterogeneous(8, 8)
    cfg = SchedulerConfig(tokens_per_step=2**19, stable_iters=3,
                          max_iters=16)
    plan = schedule(SPEC, c, P, cfg)
    assert plan.objective < math.inf
    assert len(plan.train_devices) + len(plan.infer_devices) == 16
    assert set(plan.train_devices).isdisjoint(plan.infer_devices)
    # paper's operating assumption: rollout side is the pacing stage
    assert plan.cost_infer >= plan.cost_train * 0.5


def test_scheduled_beats_uniform():
    """Table 3: optimized allocation ≥ uniform split."""
    c = paper_heterogeneous(8, 8)
    cfg = SchedulerConfig(tokens_per_step=2**19, stable_iters=3,
                          max_iters=16)
    opt = schedule(SPEC, c, P, cfg)
    uni = schedule_uniform(SPEC, c, P, cfg)
    assert opt.throughput_tokens_per_sec(cfg.tokens_per_step) >= \
        uni.throughput_tokens_per_sec(cfg.tokens_per_step) * 0.999


def test_two_phase_faster_than_exhaustive():
    """Table 5 direction: constrained search beats exhaustive wall-clock."""
    import time
    c = paper_heterogeneous(4, 4)
    t0 = time.perf_counter()
    constrained_search(SPEC, c, c.devices, tokens_per_step=2**19)
    t_fast = time.perf_counter() - t0
    t0 = time.perf_counter()
    exhaustive_search(SPEC, c, c.devices, tokens_per_step=2**19)
    t_slow = time.perf_counter() - t0
    assert t_slow > t_fast


def test_weight_sync_cost_positive_and_scales():
    c = paper_heterogeneous(8, 8)
    tr = c.devices_of_type("H800")
    inf = c.devices_of_type("H20")
    t1 = weight_sync_cost(PAPER_MODELS["1.5B"], c, tr, inf)
    t2 = weight_sync_cost(PAPER_MODELS["14B"], c, tr, inf)
    assert 0 < t1 < t2
