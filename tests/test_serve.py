"""Continuous-batching subsystem: paged cache, engine, feedback loop.

The load-bearing properties:

  * allocator — alloc/free round-trips, lazy growth, exhaustion is
    refused atomically, occupancy stats track live tokens;
  * engine vs static oracle — greedy completions token-identical on an
    equal-length batch, per-row identical on ragged batches (each row
    compared against a B=1 static run, where right-padding is a no-op),
    identical across queue pressure and preemption;
  * AReaL staleness across a mid-sequence weight swap — a trajectory
    spanning versions v, v+1 is accounted against v and the η admission
    rule in rl.buffer keeps holding;
  * feedback — ServingCostModel moves h_ψ pricing, the no-provider plan
    stays bit-identical; GenTimeModel redistributes simulated generation
    time by length without breaking simulator conservation.
"""
import jax
import numpy as np
import pytest

from repro.core.cluster import PROFILES
from repro.core.cost_model import (GenTimeModel, LengthDistribution,
                                   ReplicaConfig, replica_throughput)
from repro.core.staleness import StalenessConfig
from repro.data.tasks import MathTaskGenerator, Tokenizer
from repro.models.api import ModelConfig, get_model
from repro.rl.buffer import RolloutBuffer
from repro.rl.rollout import GenConfig, RolloutEngine
from repro.rl.weight_sync import WeightStore
from repro.serve import (EngineReport, PagedEngine, ServeConfig,
                         ServingCostModel, fit_gen_time)
from repro.serve.kv_cache import PagedKVCache

TOK = Tokenizer()
TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                   n_heads=4, n_kv_heads=2, d_ff=64, vocab=TOK.vocab_size,
                   dtype="float32", remat=False)


def _store(seed=0):
    model = get_model(TINY)
    store = WeightStore()
    store.publish(model.init(jax.random.PRNGKey(seed), TINY))
    return store


# ------------------------------------------------------------------ KV cache
def test_kv_cache_alloc_free_roundtrip():
    kv = PagedKVCache(TINY, max_slots=3, max_len=64, page_size=8)
    assert kv.maxp == 8
    assert kv.num_pages == 1 + 3 * 8          # worst case + null page
    s = kv.alloc_slot()
    assert kv.ensure(s, 20)                   # 3 pages
    assert kv.pages_in_use == 3
    assert kv.ensure(s, 21)                   # still page 3
    assert kv.pages_in_use == 3
    assert kv.ensure(s, 25)                   # grows to 4
    assert kv.pages_in_use == 4
    assert 0 not in kv.block_tables[s][:4]    # null page never allocated
    kv.seq_lens[s] = 25
    assert kv.page_occupancy() == pytest.approx(25 / 32)
    kv.free_slot(s)
    assert kv.pages_in_use == 0 and kv.free_slots == 3
    assert (kv.block_tables[s] == 0).all()    # stale table rows zeroed


def test_kv_cache_exhaustion_is_atomic():
    kv = PagedKVCache(TINY, max_slots=2, max_len=64, page_size=8,
                      num_pages=5)            # 4 usable pages
    a, b = kv.alloc_slot(), kv.alloc_slot()
    assert kv.ensure(a, 24)                   # 3 pages
    before = kv.pages_in_use
    assert not kv.ensure(b, 16)               # needs 2, only 1 left
    assert kv.pages_in_use == before          # refused atomically
    assert kv.ensure(b, 8)                    # 1 page fits
    kv.free_slot(a)
    assert kv.ensure(b, 32)                   # freed pages reusable


# ----------------------------------------------------------- engine identity
def test_equal_length_batch_token_identical():
    store = _store()
    tasks = MathTaskGenerator(seed=3).equal_length_batch(4)
    gen = GenConfig(max_new_tokens=18, segment=8, greedy=True)
    r_s, m_s = RolloutEngine(TINY, store, gen).generate(tasks)
    eng = PagedEngine(TINY, store, gen,
                      ServeConfig(max_slots=4, max_len=128, page_size=8,
                                  prefill_chunk=8))
    r_p, m_p = eng.generate(tasks)
    for a, b in zip(r_s, r_p):
        assert a.completion_ids == b.completion_ids
        assert a.prompt_ids == b.prompt_ids
        np.testing.assert_allclose(a.behavior_logp, b.behavior_logp,
                                   atol=1e-4)
    assert m_p["decode_slot_steps"] <= m_s["decode_slot_steps"]


def test_ragged_batch_matches_per_row_static():
    store = _store()
    tasks = MathTaskGenerator(seed=5).batch(5)
    eng = PagedEngine(TINY, store, GenConfig(max_new_tokens=14, greedy=True),
                      ServeConfig(max_slots=5, max_len=128, page_size=8,
                                  prefill_chunk=8))
    r_p, _ = eng.generate(tasks)
    for i, t in enumerate(tasks):
        r_s, _ = RolloutEngine(
            TINY, store, GenConfig(max_new_tokens=14, greedy=True)
        ).generate([t])
        assert r_s[0].completion_ids == r_p[i].completion_ids, i


def test_queued_admission_more_tasks_than_slots():
    store = _store()
    tasks = MathTaskGenerator(seed=7).batch(6)
    eng = PagedEngine(TINY, store, GenConfig(max_new_tokens=10, greedy=True),
                      ServeConfig(max_slots=2, max_len=64, page_size=8,
                                  prefill_chunk=8))
    r_p, m = eng.generate(tasks)
    assert len(r_p) == 6 and m["decode_steps"] > 0
    for i, t in enumerate(tasks):
        r_s, _ = RolloutEngine(
            TINY, store, GenConfig(max_new_tokens=10, greedy=True)
        ).generate([t])
        assert r_s[0].completion_ids == r_p[i].completion_ids, i


def test_preemption_recomputes_correctly():
    """A pool too small for both sequences' full contexts forces a
    vLLM-style preempt+recompute; outputs must still match the oracle."""
    store = _store()
    tasks = MathTaskGenerator(seed=9).batch(2)
    need = max(len(t.prompt_ids) for t in tasks) + 24
    eng = PagedEngine(TINY, store,
                      GenConfig(max_new_tokens=24, greedy=True, eos_id=-1),
                      ServeConfig(max_slots=2, max_len=need, page_size=8,
                                  prefill_chunk=8,
                                  num_pages=1 + (need + 7) // 8 + 2))
    r_p, m = eng.generate(tasks)
    assert m["preemptions"] >= 1
    # discarded-and-recomputed decode work must not inflate kept-token
    # metrics: occupancy counts only kept slot-steps
    kept = sum(max(len(r.completion_ids) - 1, 0) for r in r_p)
    assert m["decode_slot_steps"] - eng.stats.preempted_slot_steps == kept
    assert m["slot_occupancy"] <= 1.0
    for i, t in enumerate(tasks):
        r_s, _ = RolloutEngine(
            TINY, store, GenConfig(max_new_tokens=24, greedy=True,
                                   eos_id=-1)).generate([t])
        assert r_s[0].completion_ids == r_p[i].completion_ids, i


def test_mixed_lengths_beat_static_slot_steps():
    store = _store()
    tasks = MathTaskGenerator(seed=11).batch(4)
    lens = [4, 8, 16, 24]
    eng = PagedEngine(TINY, store,
                      GenConfig(max_new_tokens=24, greedy=True, eos_id=-1),
                      ServeConfig(max_slots=4, max_len=128, page_size=8,
                                  prefill_chunk=8))
    r_p, m_p = eng.generate(tasks, max_new_per_task=lens)
    assert [len(r.completion_ids) for r in r_p] == lens
    _, m_s = RolloutEngine(
        TINY, store, GenConfig(max_new_tokens=24, greedy=True,
                               eos_id=-1)).generate(tasks)
    assert m_p["decode_slot_steps"] < m_s["decode_slot_steps"]
    assert 0.0 < m_p["slot_occupancy"] <= 1.0


def _task_with_prompt_len(n, seed=21):
    """A MathTask whose prompt is exactly n ids (truncated/padded copy)."""
    t = MathTaskGenerator(seed=seed).sample()
    ids = (t.prompt_ids * ((n // len(t.prompt_ids)) + 1))[:n]
    from repro.data.tasks import MathTask
    return MathTask(prompt=t.prompt, answer=t.answer, prompt_ids=ids)


def test_admission_headroom_cannot_deadlock():
    """Regression: a request whose total footprint exactly fits the pool
    must admit even though the +1 decode-headroom page does not exist —
    otherwise drain() spins forever on an unadmittable queue head."""
    store = _store()
    task = _task_with_prompt_len(12)
    eng = PagedEngine(TINY, store,
                      GenConfig(max_new_tokens=4, greedy=True, eos_id=-1),
                      ServeConfig(max_slots=1, max_len=16, page_size=8,
                                  num_pages=3, prefill_chunk=8))
    r, _ = eng.generate([task])            # must terminate
    assert len(r[0].completion_ids) == 4


def test_prefill_pad_rows_past_table_do_not_corrupt():
    """Regression: the tail prefill chunk's pad rows can run past the
    block table (p0 + chunk > maxp·page near max_len); they must land in
    the null page, not alias onto the last real page over valid K/V."""
    store = _store()
    task = _task_with_prompt_len(18)
    eng = PagedEngine(TINY, store,
                      GenConfig(max_new_tokens=2, greedy=True, eos_id=-1),
                      ServeConfig(max_slots=1, max_len=20, page_size=8,
                                  prefill_chunk=16))
    r_p, _ = eng.generate([task])
    r_s, _ = RolloutEngine(
        TINY, store, GenConfig(max_new_tokens=2, greedy=True,
                               eos_id=-1)).generate([task])
    assert r_p[0].completion_ids == r_s[0].completion_ids


def test_generate_metrics_are_per_call():
    """A long-lived engine serving several batches must report each
    call's own work (the static engine's contract), not lifetime
    counters; ``collect()`` is the lifetime view."""
    store = _store()
    gen = GenConfig(max_new_tokens=8, greedy=True, eos_id=-1)
    eng = PagedEngine(TINY, store, gen,
                      ServeConfig(max_slots=2, max_len=64, page_size=8,
                                  prefill_chunk=8))
    _, m1 = eng.generate(MathTaskGenerator(seed=1).batch(2))
    _, m2 = eng.generate(MathTaskGenerator(seed=2).batch(2))
    assert m2["decode_steps"] == m1["decode_steps"]          # same workload
    assert m2["decode_slot_steps"] == m1["decode_slot_steps"]
    assert m2["weight_swaps"] == 0 and m2["preemptions"] == 0
    _, lifetime = eng.collect()
    assert lifetime["decode_slot_steps"] == (m1["decode_slot_steps"]
                                             + m2["decode_slot_steps"])


def test_non_dense_family_rejected():
    cfg = TINY.replace(family="ssm", ssm_state=16)
    with pytest.raises(ValueError, match="static RolloutEngine"):
        PagedEngine(cfg, _store(), GenConfig())


# -------------------------------------------------- staleness across a swap
def test_mid_swap_oldest_version_accounting_and_eta():
    """Satellite: a trajectory spanning weight versions v, v+1 must be
    accounted against v, and the η admission rule in rl.buffer must keep
    holding for it."""
    store = _store()
    model = get_model(TINY)
    params, _ = store.fetch(dtype=TINY.jdtype)
    eng = PagedEngine(TINY, store,
                      GenConfig(max_new_tokens=16, segment=2, greedy=True,
                                eos_id=-1),
                      ServeConfig(max_slots=2, max_len=96, page_size=8,
                                  prefill_chunk=8))
    eng.submit(MathTaskGenerator(seed=13).batch(2))
    # run until decoding is underway on v1, then publish v2 mid-sequence
    while eng.stats.decode_steps < 3:
        assert eng.step()
    store.publish(params)
    eng.drain()
    rollouts, metrics = eng.collect()
    assert metrics["weight_swaps"] >= 1
    assert metrics["versions"] == [1, 2]
    assert metrics["tokens_per_sec"] > 0    # stepwise path accrues wall time
    for r in rollouts:
        assert r.version == 1                 # oldest contributing version

    # η bookkeeping: at trainer version 2 a lag-1 rollout is admissible
    # (η=1); one more bump evicts it
    buf = RolloutBuffer(StalenessConfig(eta=1, rollouts_per_step=2))
    buf.launch(len(rollouts))
    for r in rollouts:
        buf.push(r)
    buf.bump_version()                        # v1: lag 0
    buf.bump_version()                        # v2: lag 1 == η → still held
    assert len(buf) == len(rollouts) and buf.dropped == 0
    buf.bump_version()                        # v3: lag 2 > η → evicted
    assert len(buf) == 0 and buf.dropped == len(rollouts)


# ------------------------------------------------------------ feedback loop
def test_serving_cost_model_moves_replica_pricing():
    spec_model = __import__("repro.core.model_spec",
                            fromlist=["PAPER_MODELS"]).PAPER_MODELS["1.5B"]
    P = LengthDistribution(mean_len=4096, prompt_len=512)
    cfg = ReplicaConfig("TPUv5e", (4,))
    base = replica_throughput(spec_model, cfg, P)
    rep = EngineReport(device_type="TPUv5e", engine="paged",
                       tokens_per_sec=0.0, slot_occupancy=0.8,
                       page_occupancy=0.9, batch_slots=8, decode_steps=100)
    served = replica_throughput(spec_model, cfg, P,
                                cost_provider=ServingCostModel([rep]))
    analytic_eff = PROFILES["TPUv5e"]  # engine eff table: 0.40 for v5e
    assert served.tokens_per_sec == pytest.approx(
        base.tokens_per_sec * 0.8 / 0.40, rel=1e-6)
    # uncovered type falls back to the analytic constant
    other = ReplicaConfig("TPUv5p", (4,))
    assert replica_throughput(
        spec_model, other, P,
        cost_provider=ServingCostModel([rep])).tokens_per_sec == \
        pytest.approx(replica_throughput(spec_model, other,
                                         P).tokens_per_sec, rel=1e-9)


def test_engine_report_from_stats_and_fit():
    store = _store()
    tasks = MathTaskGenerator(seed=15).batch(4)
    eng = PagedEngine(TINY, store,
                      GenConfig(max_new_tokens=20, greedy=True, eos_id=-1),
                      ServeConfig(max_slots=4, max_len=128, page_size=8,
                                  prefill_chunk=8))
    eng.generate(tasks, max_new_per_task=[5, 9, 14, 20])
    rep = EngineReport.from_stats(eng.stats, "TPUv5e")
    assert 0.0 < rep.slot_occupancy <= 1.0
    assert rep.decode_steps == eng.stats.decode_steps
    gtm = fit_gen_time(eng.stats.gen_samples, prompt_len=16.0)
    assert gtm is not None and (gtm.a > 0 or gtm.b > 0)


def test_fit_gen_time_recovers_coefficients():
    true = GenTimeModel(a=2e-3, b=1e-5, t_prefill=0.05)
    samples = [(L, true.raw(100.0, L)) for L in (50, 100, 200, 400, 800)]
    fit = fit_gen_time(samples, prompt_len=100.0)
    for L in (75, 300, 600):
        assert fit.raw(100.0, L) == pytest.approx(true.raw(100.0, L),
                                                  rel=1e-6)
    assert fit_gen_time([(10, 1.0), (10, 1.1)]) is None   # underdetermined


# ------------------------------------------------------- gen-time in the sim
def test_gen_time_model_normalization_and_convexity():
    gtm = GenTimeModel(a=1e-3, b=2e-6, t_prefill=0.01)
    P = LengthDistribution(mean_len=1000, prompt_len=200)
    # a mean-length rollout costs exactly what the constant model charged
    assert gtm.duration(1000, prompt_len=200, tokens_per_sec=500,
                        mean_len=1000) == pytest.approx(1200 / 500)
    # longer rollouts cost MORE per token (KV growth), shorter less
    d_long = gtm.duration(2000, prompt_len=200, tokens_per_sec=500,
                          mean_len=1000)
    d_short = gtm.duration(500, prompt_len=200, tokens_per_sec=500,
                           mean_len=1000)
    assert d_long / 2000 > d_short / 500


def test_simulator_consumes_gen_time_model():
    from repro.core.cluster import tpu_heterogeneous
    from repro.core.scheduler import SchedulerConfig, schedule
    from repro.sim.simulator import AsyncRLSimulator, SimConfig
    spec = __import__("repro.core.model_spec",
                      fromlist=["PAPER_MODELS"]).PAPER_MODELS["1.5B"]
    P = LengthDistribution(mean_len=4096, prompt_len=512)
    plan = schedule(spec, tpu_heterogeneous(8, 16), P,
                    SchedulerConfig(tokens_per_step=2 ** 18, stable_iters=3,
                                    max_iters=8, adapt_delta=False))
    base_cfg = SimConfig(n_steps=6, rollouts_per_step=32, eta=4,
                         check_invariants=True)
    base = AsyncRLSimulator(plan, P, base_cfg).run()
    rc = plan.rollout_plan.assignments[0].cost
    gtm = GenTimeModel.from_replica_cost(rc, P)
    assert gtm.b > 0                          # KV share exists
    aware_cfg = SimConfig(n_steps=6, rollouts_per_step=32, eta=4,
                          check_invariants=True, gen_time=gtm)
    aware = AsyncRLSimulator(plan, P, aware_cfg).run()
    # conservation holds under the new time model…
    assert aware.rollouts_launched == (aware.rollouts_trained
                                       + aware.rollouts_in_buffer
                                       + aware.rollouts_generating
                                       + aware.dropped)
    # …and the length-aware wall clock actually differs from the constant
    assert aware.wall_time_s != base.wall_time_s
    assert aware.steps == base.steps == 6
