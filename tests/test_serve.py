"""Continuous-batching subsystem: paged cache, engine, feedback loop.

The load-bearing properties:

  * allocator — alloc/free round-trips, lazy growth, exhaustion is
    refused atomically, occupancy stats track live tokens;
  * prefix sharing — fork_slot aliases without copying, the COW barrier
    copies exactly the written page, refcounts conserve the pool under
    ANY interleaving of alloc/ensure/fork/cow/free (property test), and
    a forked greedy sibling is token-identical to the oracle;
  * engine vs static oracle — greedy completions token-identical on an
    equal-length batch, per-row identical on ragged batches (each row
    compared against a B=1 static run, where right-padding is a no-op),
    identical across queue pressure and preemption;
  * AReaL staleness across a mid-sequence weight swap — a trajectory
    spanning versions v, v+1 is accounted against v and the η admission
    rule in rl.buffer keeps holding (including forked siblings, which
    inherit the leader's version provenance);
  * feedback — ServingCostModel moves h_ψ pricing AND prefill G_eff
    pricing, the no-provider plan stays bit-identical; GenTimeModel
    redistributes simulated generation time by length without breaking
    simulator conservation.
"""
import jax
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                                   # pragma: no cover
    from _prop import given, settings, st

from repro.core.cluster import PROFILES
from repro.core.cost_model import (GenTimeModel, LengthDistribution,
                                   ReplicaConfig, replica_throughput)
from repro.core.staleness import StalenessConfig
from repro.data.tasks import MathTaskGenerator, Tokenizer
from repro.models.api import ModelConfig, get_model
from repro.rl.buffer import RolloutBuffer
from repro.rl.rollout import GenConfig, RolloutEngine
from repro.rl.weight_sync import WeightStore
from repro.serve import (EngineReport, PagedEngine, ServeConfig,
                         ServingCostModel, fit_gen_time)
from repro.serve.kv_cache import PagedKVCache
from repro.serve.radix import RadixCache

TOK = Tokenizer()
TINY = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=32,
                   n_heads=4, n_kv_heads=2, d_ff=64, vocab=TOK.vocab_size,
                   dtype="float32", remat=False)


def _store(seed=0):
    model = get_model(TINY)
    store = WeightStore()
    store.publish(model.init(jax.random.PRNGKey(seed), TINY))
    return store


# ------------------------------------------------------------------ KV cache
def test_kv_cache_alloc_free_roundtrip():
    kv = PagedKVCache(TINY, max_slots=3, max_len=64, page_size=8)
    assert kv.maxp == 8
    assert kv.num_pages == 1 + 3 * 8          # worst case + null page
    s = kv.alloc_slot()
    assert kv.ensure(s, 20)                   # 3 pages
    assert kv.pages_in_use == 3
    assert kv.ensure(s, 21)                   # still page 3
    assert kv.pages_in_use == 3
    assert kv.ensure(s, 25)                   # grows to 4
    assert kv.pages_in_use == 4
    assert 0 not in kv.block_tables[s][:4]    # null page never allocated
    kv.seq_lens[s] = 25
    assert kv.page_occupancy() == pytest.approx(25 / 32)
    kv.free_slot(s)
    assert kv.pages_in_use == 0 and kv.free_slots == 3
    assert (kv.block_tables[s] == 0).all()    # stale table rows zeroed


def test_kv_cache_exhaustion_is_atomic():
    kv = PagedKVCache(TINY, max_slots=2, max_len=64, page_size=8,
                      num_pages=5)            # 4 usable pages
    a, b = kv.alloc_slot(), kv.alloc_slot()
    assert kv.ensure(a, 24)                   # 3 pages
    before = kv.pages_in_use
    assert not kv.ensure(b, 16)               # needs 2, only 1 left
    assert kv.pages_in_use == before          # refused atomically
    assert kv.ensure(b, 8)                    # 1 page fits
    kv.free_slot(a)
    assert kv.ensure(b, 32)                   # freed pages reusable


# ------------------------------------------------------------ prefix sharing
def test_fork_slot_aliases_without_copy_and_cow_diverges():
    kv = PagedKVCache(TINY, max_slots=3, max_len=64, page_size=8)
    parent = kv.alloc_slot()
    assert kv.ensure(parent, 20)               # 3 pages, last one partial
    kv.seq_lens[parent] = 20
    before = kv.pages_in_use
    child = kv.fork_slot(parent, 20)
    assert child is not None and child != parent
    assert kv.pages_in_use == before           # aliasing moved no pages
    assert (kv.block_tables[child][:3] == kv.block_tables[parent][:3]).all()
    assert kv.shared_pages == 3
    # divergent write into the partial tail page copies exactly that page
    tail = kv.block_tables[child][2]
    assert kv.writable(child, 20)
    assert kv.cow_copies == 1
    assert kv.block_tables[child][2] != tail          # child got a copy
    assert kv.block_tables[parent][2] == tail         # parent keeps original
    assert (kv.block_tables[child][:2] == kv.block_tables[parent][:2]).all()
    assert kv.pages_in_use == before + 1
    # ref==1 writes are free: no further copy
    assert kv.writable(child, 20) and kv.cow_copies == 1
    # frees decrement; pool conserved throughout
    kv.free_slot(parent)
    assert kv.pages_in_use == 3                # child holds 2 shared + 1 own
    kv.free_slot(child)
    assert kv.pages_in_use == 0
    assert kv.free_pages == kv.num_pages - 1


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 1 << 20), min_size=1, max_size=40))
def test_refcount_conservation_property(ops):
    """Any interleaving of alloc/ensure/fork/cow-write/free with radix
    insert/match/evict keeps the pool conserved: physical pages_in_use +
    free_pages == num_pages − 1, every live block-table entry names a
    page with refcount > 0, no page sits on the free list while still
    referenced, and no radix-tree node references a freed page.  After
    freeing every slot and resetting the tree, the pool is whole."""
    kv = PagedKVCache(TINY, max_slots=4, max_len=64, page_size=8,
                      num_pages=11)
    radix = RadixCache(kv)

    def _toks(x, n):
        return [(x + 7 * i) % 250 + 3 for i in range(n)]

    live = []
    for x in ops:
        op = x % 8
        if op == 0:
            s = kv.alloc_slot()
            if s is not None:
                live.append(s)
        elif op == 1 and live:
            kv.free_slot(live.pop((x // 8) % len(live)))
        elif op == 2 and live:
            s = live[(x // 8) % len(live)]
            kv.ensure(s, (x // 64) % 70)       # may exceed max_len: refused
        elif op == 3 and live:
            parent = live[(x // 8) % len(live)]
            covered = len(kv._pages_of[parent]) * kv.page
            if covered:
                child = kv.fork_slot(parent, 1 + (x // 64) % covered)
                if child is not None:
                    live.append(child)
        elif op == 4 and live:
            s = live[(x // 8) % len(live)]
            covered = len(kv._pages_of[s]) * kv.page
            if covered:
                kv.writable(s, (x // 64) % covered)
        elif op == 5 and live:
            # cache a live slot's page-aligned prefix in the tree (the
            # tree co-owns the pages alongside the slot)
            s = live[(x // 8) % len(live)]
            npages = len(kv._pages_of[s])
            if npages:
                k = 1 + (x // 64) % npages
                radix.insert(_toks(x // 512, k * kv.page),
                             kv._pages_of[s][:k])
        elif op == 6:
            # same token universe as the inserts, so matches really hit
            radix.match(_toks(x // 512, kv.page * (1 + x // 8 % 3)))
        elif op == 7:
            radix.evict(1 + (x // 8) % 4)
        # --- invariants after every operation
        assert kv.pages_in_use + kv.free_pages == kv.num_pages - 1
        assert kv._ref[0] == 0                 # null page never owned
        free = set(kv._free_pages)
        assert all(kv._ref[p] == 0 for p in free)
        for s in live:
            owned = kv._pages_of[s]
            for i, pid in enumerate(owned):
                assert kv._ref[pid] > 0, "live table references a dead page"
                assert kv.block_tables[s, i] == pid
                assert pid not in free
            assert (kv.block_tables[s, len(owned):] == 0).all()
        stack = list(radix.root.children.values())
        while stack:
            node = stack.pop()
            for pid in node.pages:
                assert kv._ref[pid] > 0, "tree references a dead page"
                assert pid not in free
            stack.extend(node.children.values())
    # teardown drains every owner: slots, then the tree — pool is whole
    for s in live:
        kv.free_slot(s)
    radix.reset()
    assert kv.pages_in_use == 0
    assert kv.free_pages == kv.num_pages - 1


# ~6s: 3-sibling COW generation vs 3 independent runs; the fork
# identity itself is CI-gated by the fig10 --tiny smoke.
@pytest.mark.slow
def test_submit_group_siblings_token_identical_and_share_prefill():
    store = _store()
    task = MathTaskGenerator(seed=19).sample()
    gen = GenConfig(max_new_tokens=12, greedy=True, eos_id=-1)
    oracle, _ = RolloutEngine(TINY, store, gen).generate([task])
    eng = PagedEngine(TINY, store, gen,
                      ServeConfig(max_slots=4, max_len=128, page_size=8,
                                  prefill_chunk=8))
    eng.submit_group(task, 4, group_id=9)
    eng.drain()
    rollouts, m = eng.collect()
    assert len(rollouts) == 4
    for r in rollouts:
        assert r.completion_ids == oracle[0].completion_ids
        assert r.group_id == 9
    plen = len(task.prompt_ids)
    assert m["prefill_tokens"] == plen              # prompt computed ONCE
    assert m["prefill_tokens_shared"] == 3 * plen
    assert m["forks"] == 3
    assert m["g_eff"] == pytest.approx(4.0)
    assert m["prefix_hit_rate"] == pytest.approx(0.75)


def test_admission_dedupes_identical_prompts_outside_groups():
    """Two separate submits of the SAME prompt must coalesce into one
    prefill (hash-based admission dedupe), not two."""
    store = _store()
    task = MathTaskGenerator(seed=23).sample()
    gen = GenConfig(max_new_tokens=10, greedy=True, eos_id=-1)
    eng = PagedEngine(TINY, store, gen,
                      ServeConfig(max_slots=2, max_len=64, page_size=8,
                                  prefill_chunk=8))
    eng.submit([task])
    eng.submit([task])                 # separate call, identical prompt
    eng.drain()
    rollouts, m = eng.collect()
    assert len(rollouts) == 2
    assert rollouts[0].completion_ids == rollouts[1].completion_ids
    assert m["forks"] == 1
    assert m["prefill_tokens"] == len(task.prompt_ids)


def test_admission_dedupe_keys_on_sampling_params():
    """Identical prompts with DIFFERENT sampling params must not alias
    into one fork group — the dedupe key is (prompt, params, max_new),
    not the prompt hash alone."""
    store = _store()
    task = MathTaskGenerator(seed=23).sample()
    gen = GenConfig(max_new_tokens=10, greedy=True, eos_id=-1)
    eng = PagedEngine(TINY, store, gen,
                      ServeConfig(max_slots=4, max_len=64, page_size=8,
                                  prefill_chunk=8))
    eng.submit([task])                         # engine defaults (greedy)
    eng.submit([task], temperature=0.7, greedy=False)
    eng.submit([task], top_p=0.9, greedy=False)
    eng.drain()
    rollouts, m = eng.collect()
    assert len(rollouts) == 3
    assert m["forks"] == 0                     # three distinct param sets
    assert m["prefill_tokens"] == 3 * len(task.prompt_ids)
    # same params DO coalesce (baseline behavior preserved)
    eng2 = PagedEngine(TINY, store, gen,
                       ServeConfig(max_slots=4, max_len=64, page_size=8,
                                   prefill_chunk=8))
    eng2.submit([task], temperature=0.7, greedy=False)
    eng2.submit([task], temperature=0.7, greedy=False)
    eng2.drain()
    _, m2 = eng2.collect()
    assert m2["forks"] == 1
    assert m2["prefill_tokens"] == len(task.prompt_ids)


# -------------------------------------------------------------- radix cache
def test_radix_tree_match_insert_split_evict():
    kv = PagedKVCache(TINY, max_slots=4, max_len=64, page_size=8,
                      num_pages=17)
    rx = RadixCache(kv)
    s = kv.alloc_slot()
    kv.ensure(s, 32)                           # 4 pages
    pages = list(kv._pages_of[s])
    seq = list(range(3, 35))                   # 32 tokens, page-aligned
    assert rx.insert(seq, pages) == 4
    assert rx.cached_pages == 4 and rx.n_nodes == 1
    # full and partial matches are page-aligned
    got, n = rx.match(seq)
    assert n == 32 and got == pages
    got, n = rx.match(seq[:20])                # 2.5 pages → 2 pages
    assert n == 16 and got == pages[:2]
    _, n = rx.match([99] * 16)
    assert n == 0
    # diverging insert splits at the page boundary
    s2 = kv.alloc_slot()
    kv.ensure(s2, 16)
    seq2 = seq[:16] + [200] * 16               # shares 2 pages, then forks
    rx.insert(seq2, pages[:2] + list(kv._pages_of[s2]))
    assert rx.n_nodes == 3                     # prefix + two branches
    assert rx.cached_pages == 6
    # eviction removes LRU leaves only; interior prefix survives
    kv.free_slot(s)
    kv.free_slot(s2)
    freed = rx.evict(2)
    assert freed >= 2 and rx.n_nodes == 2
    rx.reset()
    assert rx.cached_pages == 0
    assert kv.pages_in_use == 0
    assert kv.free_pages == kv.num_pages - 1


def test_radix_resubmit_hits_tree_token_identically():
    """An identical prompt resubmitted AFTER the first completed (no live
    fork leader) is served from the radix tree: page-aligned prompt K/V
    adopted, only the tail prefilled, same tokens as a cold engine."""
    store = _store()
    task = MathTaskGenerator(seed=29).sample()
    gen = GenConfig(max_new_tokens=12, greedy=True, eos_id=-1)
    sv = dict(max_slots=2, max_len=96, page_size=8, prefill_chunk=8)
    cold = PagedEngine(TINY, store, gen, ServeConfig(**sv))
    warm = PagedEngine(TINY, store, gen, ServeConfig(**sv, radix=True))
    c1, _ = cold.generate([task])
    w1, m1 = warm.generate([task])
    assert m1["radix_hit_tokens"] == 0         # nothing cached yet
    c2, _ = cold.generate([task])
    w2, m2 = warm.generate([task])
    assert c1[0].completion_ids == w1[0].completion_ids
    assert c2[0].completion_ids == w2[0].completion_ids
    plen = len(task.prompt_ids)
    expect = ((plen - 1) // 8) * 8             # capped: last token prefills
    assert m2["radix_hit_tokens"] == expect > 0
    assert m2["prefill_tokens"] == plen - expect
    # pool conserved with the tree live
    assert warm.kv.pages_in_use + warm.kv.free_pages == warm.kv.num_pages - 1


def test_radix_reset_on_weight_swap():
    """Cached K/V is stale after a weight swap: the tree resets (swaps
    happen at segment boundaries, so an in-between request absorbs the
    swap), and the next identical prompt re-prefills in full under the
    new weights instead of hitting poisoned cache."""
    store = _store()
    gen_ = MathTaskGenerator(seed=31)
    task, other = gen_.sample(), gen_.sample()
    gen = GenConfig(max_new_tokens=8, segment=1, greedy=True, eos_id=-1)
    eng = PagedEngine(TINY, store, gen,
                      ServeConfig(max_slots=2, max_len=96, page_size=8,
                                  prefill_chunk=8, radix=True))
    eng.generate([task])
    assert eng.radix.n_nodes > 0
    model = get_model(TINY)
    store.publish(model.init(jax.random.PRNGKey(99), TINY))
    _, m_other = eng.generate([other])         # swap lands here; tree drops
    assert m_other["weight_swaps"] == 1
    _, m = eng.generate([task])
    assert m["radix_hit_tokens"] == 0          # task's entry did not survive
    assert m["prefill_tokens"] == len(task.prompt_ids)
    assert eng.radix.n_nodes > 0               # post-swap completions cached


def test_share_prefix_disabled_prefills_every_request():
    store = _store()
    task = MathTaskGenerator(seed=23).sample()
    gen = GenConfig(max_new_tokens=8, greedy=True, eos_id=-1)
    eng = PagedEngine(TINY, store, gen,
                      ServeConfig(max_slots=4, max_len=64, page_size=8,
                                  prefill_chunk=8, share_prefix=False))
    eng.submit_group(task, 4)
    eng.drain()
    _, m = eng.collect()
    assert m["forks"] == 0 and m["cow_copies"] == 0
    assert m["g_eff"] == 1.0
    assert m["prefill_tokens"] == 4 * len(task.prompt_ids)


def test_group_preemption_recomputes_correctly():
    """A pool too small for the whole group mid-decode forces COW misses
    and preemptions; every sibling must still match the oracle."""
    store = _store()
    task = MathTaskGenerator(seed=27).sample()
    gen = GenConfig(max_new_tokens=24, greedy=True, eos_id=-1)
    oracle, _ = RolloutEngine(TINY, store, gen).generate([task])
    plen = len(task.prompt_ids)
    # room for the prompt + roughly two divergent siblings
    num_pages = 1 + (plen + 7) // 8 + 2 * ((plen + 24 + 7) // 8)
    eng = PagedEngine(TINY, store, gen,
                      ServeConfig(max_slots=4, max_len=plen + 24,
                                  page_size=8, prefill_chunk=8,
                                  num_pages=num_pages))
    eng.submit_group(task, 4)
    eng.drain()
    rollouts, m = eng.collect()
    assert len(rollouts) == 4
    for r in rollouts:
        assert r.completion_ids == oracle[0].completion_ids
    assert m["preemptions"] >= 1
    assert 0.0 < m["slot_occupancy"] <= 1.0


def test_preempted_fork_rolls_back_shared_prefill_credit():
    """A forked sibling that gets preempted recomputes its prompt solo,
    so its shared-prefill credit is void — g_eff must not overstate
    sharing to the scheduler in the preemption-thrash regime."""
    store = _store()
    task = MathTaskGenerator(seed=37).sample()
    gen = GenConfig(max_new_tokens=6, greedy=True, eos_id=-1)
    oracle, _ = RolloutEngine(TINY, store, gen).generate([task])
    eng = PagedEngine(TINY, store, gen,
                      ServeConfig(max_slots=2, max_len=64, page_size=8,
                                  prefill_chunk=32))
    eng.submit_group(task, 2)
    while eng.stats.forks < 1:
        assert eng.step()
    plen = len(task.prompt_ids)
    assert eng.stats.prefill_tokens_shared == plen
    # the fork is the youngest non-protected sequence → preempted
    assert eng._preempt_youngest()
    assert eng.stats.prefill_tokens_shared == 0
    eng.drain()
    rollouts, m = eng.collect()
    assert m["prefill_tokens"] == 2 * plen     # sibling recomputed solo
    assert m["g_eff"] == 1.0 and m["prefix_hit_rate"] == 0.0
    for r in rollouts:
        assert r.completion_ids == oracle[0].completion_ids


def test_headroom_short_waits_instead_of_duplicate_leader():
    """When fork headroom is short, the next identical-prompt request
    must WAIT for the active leader rather than admit as a second leader
    that duplicates the prompt prefill at higher page cost."""
    store = _store()
    task = _task_with_prompt_len(20, seed=35)
    gen = GenConfig(max_new_tokens=4, greedy=True, eos_id=-1)
    oracle, _ = RolloutEngine(TINY, store, gen).generate([task])
    # pool: prompt pages + 2 → one fork coalesces, the second must wait
    eng = PagedEngine(TINY, store, gen,
                      ServeConfig(max_slots=3, max_len=24, page_size=8,
                                  prefill_chunk=8, num_pages=6))
    eng.submit_group(task, 3)
    while eng.step():
        prefilling = [r for r in eng._active.values()
                      if r.state == "PREFILL"]
        assert len(prefilling) <= 1    # never two leaders of one prompt
    rollouts, m = eng.collect()
    assert len(rollouts) == 3
    for r in rollouts:
        assert r.completion_ids == oracle[0].completion_ids
    assert m["forks"] >= 1 and m["preemptions"] == 0
    # the prompt was computed once per LEADER (2 leaders: the original
    # and the waiter re-admitted after the group drained), never thrice
    assert m["prefill_tokens"] == 2 * len(task.prompt_ids)


def test_preempted_leader_drags_pending_forks():
    """A mid-prefill leader chosen as preemption victim must take its
    pending FORK siblings back to the queue with it (they have no pages
    to fork from once the leader is gone) — and the whole group must
    still recompute correctly afterwards."""
    store = _store()
    task = MathTaskGenerator(seed=33).sample()
    gen = GenConfig(max_new_tokens=8, greedy=True, eos_id=-1)
    oracle, _ = RolloutEngine(TINY, store, gen).generate([task])
    eng = PagedEngine(TINY, store, gen,
                      ServeConfig(max_slots=3, max_len=64, page_size=8,
                                  prefill_chunk=8))
    eng.submit_group(task, 3)
    assert eng.step()                  # admit leader + 2 FORK siblings
    leaders = [r for r in eng._active.values() if r.state == "PREFILL"]
    assert len(leaders) == 1 and len(leaders[0].forks) == 2
    # make the mid-prefill leader the preemption victim (a requeue corner
    # reachable when an older preempted request coalesces under a newer
    # leader's group)
    leaders[0].idx = 99
    assert eng._preempt_youngest()
    assert not eng._active and len(eng._queue) == 3
    assert all(r.state == "QUEUED" and r.slot == -1 and not r.forks
               and r.parent is None for r in eng._queue)
    eng.drain()
    rollouts, _ = eng.collect()
    assert len(rollouts) == 3
    for r in rollouts:
        assert r.completion_ids == oracle[0].completion_ids


def test_forked_siblings_inherit_leader_version_provenance():
    """Weight swap lands between the leader's admission and a sibling's
    completion: every group member is accounted against the OLDEST
    version its K/V touched (the leader's), so η admission keeps
    holding for forks."""
    store = _store()
    params, _ = store.fetch(dtype=TINY.jdtype)
    task = MathTaskGenerator(seed=29).sample()
    eng = PagedEngine(TINY, store,
                      GenConfig(max_new_tokens=16, segment=2, greedy=True,
                                eos_id=-1),
                      ServeConfig(max_slots=3, max_len=96, page_size=8,
                                  prefill_chunk=8))
    eng.submit_group(task, 3)
    while eng.stats.decode_steps < 3:
        assert eng.step()
    store.publish(params)                       # v2 mid-group
    eng.drain()
    rollouts, metrics = eng.collect()
    assert metrics["weight_swaps"] >= 1 and metrics["versions"] == [1, 2]
    assert len(rollouts) == 3
    for r in rollouts:
        assert r.version == 1                   # oldest, for every sibling


def test_block_table_upload_cache():
    """Steady decode must not re-upload the block table every step: the
    device copy is cached and refreshed only when the allocator dirtied
    the host table."""
    store = _store()
    eng = PagedEngine(TINY, store,
                      GenConfig(max_new_tokens=40, greedy=True, eos_id=-1),
                      ServeConfig(max_slots=2, max_len=128, page_size=8,
                                  prefill_chunk=8))
    _, m = eng.generate(MathTaskGenerator(seed=31).equal_length_batch(2))
    assert m["decode_steps"] >= 30
    assert 1 <= m["bt_uploads"] < m["decode_steps"] // 2


# ----------------------------------------------------------- engine identity
def test_equal_length_batch_token_identical():
    store = _store()
    tasks = MathTaskGenerator(seed=3).equal_length_batch(4)
    gen = GenConfig(max_new_tokens=18, segment=8, greedy=True)
    r_s, m_s = RolloutEngine(TINY, store, gen).generate(tasks)
    eng = PagedEngine(TINY, store, gen,
                      ServeConfig(max_slots=4, max_len=128, page_size=8,
                                  prefill_chunk=8))
    r_p, m_p = eng.generate(tasks)
    for a, b in zip(r_s, r_p):
        assert a.completion_ids == b.completion_ids
        assert a.prompt_ids == b.prompt_ids
        np.testing.assert_allclose(a.behavior_logp, b.behavior_logp,
                                   atol=1e-4)
    assert m_p["decode_slot_steps"] <= m_s["decode_slot_steps"]


# ~9s: per-row static reference re-generates the whole ragged batch
# row by row; fig9 --tiny keeps the token-identity gate in CI.
@pytest.mark.slow
def test_ragged_batch_matches_per_row_static():
    store = _store()
    tasks = MathTaskGenerator(seed=5).batch(5)
    eng = PagedEngine(TINY, store, GenConfig(max_new_tokens=14, greedy=True),
                      ServeConfig(max_slots=5, max_len=128, page_size=8,
                                  prefill_chunk=8))
    r_p, _ = eng.generate(tasks)
    for i, t in enumerate(tasks):
        r_s, _ = RolloutEngine(
            TINY, store, GenConfig(max_new_tokens=14, greedy=True)
        ).generate([t])
        assert r_s[0].completion_ids == r_p[i].completion_ids, i


# ~9s: 12 tasks through 4 slots end-to-end; admission-order logic is
# also exercised by the (fast) dedup and headroom tests above.
@pytest.mark.slow
def test_queued_admission_more_tasks_than_slots():
    store = _store()
    tasks = MathTaskGenerator(seed=7).batch(6)
    eng = PagedEngine(TINY, store, GenConfig(max_new_tokens=10, greedy=True),
                      ServeConfig(max_slots=2, max_len=64, page_size=8,
                                  prefill_chunk=8))
    r_p, m = eng.generate(tasks)
    assert len(r_p) == 6 and m["decode_steps"] > 0
    for i, t in enumerate(tasks):
        r_s, _ = RolloutEngine(
            TINY, store, GenConfig(max_new_tokens=10, greedy=True)
        ).generate([t])
        assert r_s[0].completion_ids == r_p[i].completion_ids, i


def test_preemption_recomputes_correctly():
    """A pool too small for both sequences' full contexts forces a
    vLLM-style preempt+recompute; outputs must still match the oracle."""
    store = _store()
    tasks = MathTaskGenerator(seed=9).batch(2)
    need = max(len(t.prompt_ids) for t in tasks) + 24
    eng = PagedEngine(TINY, store,
                      GenConfig(max_new_tokens=24, greedy=True, eos_id=-1),
                      ServeConfig(max_slots=2, max_len=need, page_size=8,
                                  prefill_chunk=8,
                                  num_pages=1 + (need + 7) // 8 + 2))
    r_p, m = eng.generate(tasks)
    assert m["preemptions"] >= 1
    # discarded-and-recomputed decode work must not inflate kept-token
    # metrics: occupancy counts only kept slot-steps
    kept = sum(max(len(r.completion_ids) - 1, 0) for r in r_p)
    assert m["decode_slot_steps"] - eng.stats.preempted_slot_steps == kept
    assert m["slot_occupancy"] <= 1.0
    for i, t in enumerate(tasks):
        r_s, _ = RolloutEngine(
            TINY, store, GenConfig(max_new_tokens=24, greedy=True,
                                   eos_id=-1)).generate([t])
        assert r_s[0].completion_ids == r_p[i].completion_ids, i


def test_mixed_lengths_beat_static_slot_steps():
    store = _store()
    tasks = MathTaskGenerator(seed=11).batch(4)
    lens = [4, 8, 16, 24]
    eng = PagedEngine(TINY, store,
                      GenConfig(max_new_tokens=24, greedy=True, eos_id=-1),
                      ServeConfig(max_slots=4, max_len=128, page_size=8,
                                  prefill_chunk=8))
    r_p, m_p = eng.generate(tasks, max_new_per_task=lens)
    assert [len(r.completion_ids) for r in r_p] == lens
    _, m_s = RolloutEngine(
        TINY, store, GenConfig(max_new_tokens=24, greedy=True,
                               eos_id=-1)).generate(tasks)
    assert m_p["decode_slot_steps"] < m_s["decode_slot_steps"]
    assert 0.0 < m_p["slot_occupancy"] <= 1.0


def _task_with_prompt_len(n, seed=21):
    """A MathTask whose prompt is exactly n ids (truncated/padded copy)."""
    t = MathTaskGenerator(seed=seed).sample()
    ids = (t.prompt_ids * ((n // len(t.prompt_ids)) + 1))[:n]
    from repro.data.tasks import MathTask
    return MathTask(prompt=t.prompt, answer=t.answer, prompt_ids=ids)


def test_admission_headroom_cannot_deadlock():
    """Regression: a request whose total footprint exactly fits the pool
    must admit even though the +1 decode-headroom page does not exist —
    otherwise drain() spins forever on an unadmittable queue head."""
    store = _store()
    task = _task_with_prompt_len(12)
    eng = PagedEngine(TINY, store,
                      GenConfig(max_new_tokens=4, greedy=True, eos_id=-1),
                      ServeConfig(max_slots=1, max_len=16, page_size=8,
                                  num_pages=3, prefill_chunk=8))
    r, _ = eng.generate([task])            # must terminate
    assert len(r[0].completion_ids) == 4


def test_prefill_pad_rows_past_table_do_not_corrupt():
    """Regression: the tail prefill chunk's pad rows can run past the
    block table (p0 + chunk > maxp·page near max_len); they must land in
    the null page, not alias onto the last real page over valid K/V."""
    store = _store()
    task = _task_with_prompt_len(18)
    eng = PagedEngine(TINY, store,
                      GenConfig(max_new_tokens=2, greedy=True, eos_id=-1),
                      ServeConfig(max_slots=1, max_len=20, page_size=8,
                                  prefill_chunk=16))
    r_p, _ = eng.generate([task])
    r_s, _ = RolloutEngine(
        TINY, store, GenConfig(max_new_tokens=2, greedy=True,
                               eos_id=-1)).generate([task])
    assert r_p[0].completion_ids == r_s[0].completion_ids


def test_generate_metrics_are_per_call():
    """A long-lived engine serving several batches must report each
    call's own work (the static engine's contract), not lifetime
    counters; ``collect()`` is the lifetime view."""
    store = _store()
    gen = GenConfig(max_new_tokens=8, greedy=True, eos_id=-1)
    eng = PagedEngine(TINY, store, gen,
                      ServeConfig(max_slots=2, max_len=64, page_size=8,
                                  prefill_chunk=8))
    _, m1 = eng.generate(MathTaskGenerator(seed=1).batch(2))
    _, m2 = eng.generate(MathTaskGenerator(seed=2).batch(2))
    assert m2["decode_steps"] == m1["decode_steps"]          # same workload
    assert m2["decode_slot_steps"] == m1["decode_slot_steps"]
    assert m2["weight_swaps"] == 0 and m2["preemptions"] == 0
    _, lifetime = eng.collect()
    assert lifetime["decode_slot_steps"] == (m1["decode_slot_steps"]
                                             + m2["decode_slot_steps"])


def test_non_dense_family_rejected():
    cfg = TINY.replace(family="ssm", ssm_state=16)
    with pytest.raises(ValueError, match="static RolloutEngine"):
        PagedEngine(cfg, _store(), GenConfig())


# -------------------------------------------------- staleness across a swap
def test_mid_swap_oldest_version_accounting_and_eta():
    """Satellite: a trajectory spanning weight versions v, v+1 must be
    accounted against v, and the η admission rule in rl.buffer must keep
    holding for it."""
    store = _store()
    model = get_model(TINY)
    params, _ = store.fetch(dtype=TINY.jdtype)
    eng = PagedEngine(TINY, store,
                      GenConfig(max_new_tokens=16, segment=2, greedy=True,
                                eos_id=-1),
                      ServeConfig(max_slots=2, max_len=96, page_size=8,
                                  prefill_chunk=8))
    eng.submit(MathTaskGenerator(seed=13).batch(2))
    # run until decoding is underway on v1, then publish v2 mid-sequence
    while eng.stats.decode_steps < 3:
        assert eng.step()
    store.publish(params)
    eng.drain()
    rollouts, metrics = eng.collect()
    assert metrics["weight_swaps"] >= 1
    assert metrics["versions"] == [1, 2]
    assert metrics["tokens_per_sec"] > 0    # stepwise path accrues wall time
    for r in rollouts:
        assert r.version == 1                 # oldest contributing version

    # η bookkeeping: at trainer version 2 a lag-1 rollout is admissible
    # (η=1); one more bump evicts it
    buf = RolloutBuffer(StalenessConfig(eta=1, rollouts_per_step=2))
    buf.launch(len(rollouts))
    for r in rollouts:
        buf.push(r)
    buf.bump_version()                        # v1: lag 0
    buf.bump_version()                        # v2: lag 1 == η → still held
    assert len(buf) == len(rollouts) and buf.dropped == 0
    buf.bump_version()                        # v3: lag 2 > η → evicted
    assert len(buf) == 0 and buf.dropped == len(rollouts)


# ------------------------------------------------------------ feedback loop
def test_serving_cost_model_moves_replica_pricing():
    spec_model = __import__("repro.core.model_spec",
                            fromlist=["PAPER_MODELS"]).PAPER_MODELS["1.5B"]
    P = LengthDistribution(mean_len=4096, prompt_len=512)
    cfg = ReplicaConfig("TPUv5e", (4,))
    base = replica_throughput(spec_model, cfg, P)
    rep = EngineReport(device_type="TPUv5e", engine="paged",
                       tokens_per_sec=0.0, slot_occupancy=0.8,
                       page_occupancy=0.9, batch_slots=8, decode_steps=100)
    served = replica_throughput(spec_model, cfg, P,
                                cost_provider=ServingCostModel([rep]))
    analytic_eff = PROFILES["TPUv5e"]  # engine eff table: 0.40 for v5e
    assert served.tokens_per_sec == pytest.approx(
        base.tokens_per_sec * 0.8 / 0.40, rel=1e-6)
    # uncovered type falls back to the analytic constant
    other = ReplicaConfig("TPUv5p", (4,))
    assert replica_throughput(
        spec_model, other, P,
        cost_provider=ServingCostModel([rep])).tokens_per_sec == \
        pytest.approx(replica_throughput(spec_model, other,
                                         P).tokens_per_sec, rel=1e-9)


def test_prefill_g_eff_prices_replica_prefill():
    spec_model = __import__("repro.core.model_spec",
                            fromlist=["PAPER_MODELS"]).PAPER_MODELS["1.5B"]
    # prompt-heavy profile: prefill matters, so G_eff visibly moves h_ψ
    P = LengthDistribution(mean_len=512, prompt_len=4096, max_len=8192)
    cfg = ReplicaConfig("TPUv5e", (4,))
    base = replica_throughput(spec_model, cfg, P)
    rep = EngineReport(device_type="TPUv5e", engine="paged",
                       tokens_per_sec=0.0, slot_occupancy=0.4,
                       page_occupancy=0.9, batch_slots=8, decode_steps=100,
                       prefix_hit_rate=0.875, g_eff=8.0)
    served = replica_throughput(spec_model, cfg, P,
                                cost_provider=ServingCostModel([rep]))
    # prefill time divided by G_eff exactly; decode roofline untouched
    import dataclasses as dc
    served_g1 = replica_throughput(
        spec_model, cfg, P,
        cost_provider=ServingCostModel([dc.replace(rep, g_eff=1.0)]))
    assert served.prefill_time == pytest.approx(served_g1.prefill_time / 8.0)
    assert served.decode_step_time == served_g1.decode_step_time
    assert served.tokens_per_sec > served_g1.tokens_per_sec
    # default provider reports 1.0 → bit-identical to no provider
    from repro.core.cost_model import ANALYTIC, AnalyticCostModel
    assert AnalyticCostModel().prefill_g_eff(PROFILES["TPUv5e"]) == 1.0
    assert "prefill_g_eff" in ANALYTIC.factors(PROFILES["TPUv5e"])
    assert replica_throughput(
        spec_model, cfg, P,
        cost_provider=AnalyticCostModel()).tokens_per_sec \
        == base.tokens_per_sec
    # a type without a report falls back to 1.0
    other = ReplicaConfig("TPUv5p", (4,))
    assert replica_throughput(
        spec_model, other, P,
        cost_provider=ServingCostModel([rep])).tokens_per_sec == \
        replica_throughput(spec_model, other, P).tokens_per_sec
    # g_eff < 1 from a degenerate report is clamped: sharing cannot hurt
    bad = dc.replace(rep, g_eff=0.25)
    assert ServingCostModel([bad]).prefill_g_eff(PROFILES["TPUv5e"]) == 1.0


def test_gen_time_model_g_eff_amortizes_prefill():
    gtm1 = GenTimeModel(a=1e-3, b=0.0, t_prefill=0.8)
    gtm8 = GenTimeModel(a=1e-3, b=0.0, t_prefill=0.8, g_eff=8.0)
    assert gtm8.raw(100.0, 50) == pytest.approx(
        gtm1.raw(100.0, 50) - 0.8 + 0.1)
    # fit carries the knob through; default stays bit-identical
    true = GenTimeModel(a=2e-3, b=1e-5, t_prefill=0.05)
    samples = [(L, true.raw(100.0, L)) for L in (50, 100, 200, 400)]
    fit = fit_gen_time(samples, prompt_len=100.0, g_eff=4.0)
    assert fit.g_eff == 4.0
    assert fit.raw(100.0, 200) < true.raw(100.0, 200)


def test_engine_report_from_stats_and_fit():
    store = _store()
    tasks = MathTaskGenerator(seed=15).batch(4)
    eng = PagedEngine(TINY, store,
                      GenConfig(max_new_tokens=20, greedy=True, eos_id=-1),
                      ServeConfig(max_slots=4, max_len=128, page_size=8,
                                  prefill_chunk=8))
    eng.generate(tasks, max_new_per_task=[5, 9, 14, 20])
    rep = EngineReport.from_stats(eng.stats, "TPUv5e")
    assert 0.0 < rep.slot_occupancy <= 1.0
    assert rep.decode_steps == eng.stats.decode_steps
    gtm = fit_gen_time(eng.stats.gen_samples, prompt_len=16.0)
    assert gtm is not None and (gtm.a > 0 or gtm.b > 0)


def test_fit_gen_time_recovers_coefficients():
    true = GenTimeModel(a=2e-3, b=1e-5, t_prefill=0.05)
    samples = [(L, true.raw(100.0, L)) for L in (50, 100, 200, 400, 800)]
    fit = fit_gen_time(samples, prompt_len=100.0)
    for L in (75, 300, 600):
        assert fit.raw(100.0, L) == pytest.approx(true.raw(100.0, L),
                                                  rel=1e-6)
    assert fit_gen_time([(10, 1.0), (10, 1.1)]) is None   # underdetermined


# ------------------------------------------------------- gen-time in the sim
def test_gen_time_model_normalization_and_convexity():
    gtm = GenTimeModel(a=1e-3, b=2e-6, t_prefill=0.01)
    P = LengthDistribution(mean_len=1000, prompt_len=200)
    # a mean-length rollout costs exactly what the constant model charged
    assert gtm.duration(1000, prompt_len=200, tokens_per_sec=500,
                        mean_len=1000) == pytest.approx(1200 / 500)
    # longer rollouts cost MORE per token (KV growth), shorter less
    d_long = gtm.duration(2000, prompt_len=200, tokens_per_sec=500,
                          mean_len=1000)
    d_short = gtm.duration(500, prompt_len=200, tokens_per_sec=500,
                           mean_len=1000)
    assert d_long / 2000 > d_short / 500


def test_simulator_consumes_gen_time_model():
    from repro.core.cluster import tpu_heterogeneous
    from repro.core.scheduler import SchedulerConfig, schedule
    from repro.sim.simulator import AsyncRLSimulator, SimConfig
    spec = __import__("repro.core.model_spec",
                      fromlist=["PAPER_MODELS"]).PAPER_MODELS["1.5B"]
    P = LengthDistribution(mean_len=4096, prompt_len=512)
    plan = schedule(spec, tpu_heterogeneous(8, 16), P,
                    SchedulerConfig(tokens_per_step=2 ** 18, stable_iters=3,
                                    max_iters=8, adapt_delta=False))
    base_cfg = SimConfig(n_steps=6, rollouts_per_step=32, eta=4,
                         check_invariants=True)
    base = AsyncRLSimulator(plan, P, base_cfg).run()
    rc = plan.rollout_plan.assignments[0].cost
    gtm = GenTimeModel.from_replica_cost(rc, P)
    assert gtm.b > 0                          # KV share exists
    aware_cfg = SimConfig(n_steps=6, rollouts_per_step=32, eta=4,
                          check_invariants=True, gen_time=gtm)
    aware = AsyncRLSimulator(plan, P, aware_cfg).run()
    # conservation holds under the new time model…
    assert aware.rollouts_launched == (aware.rollouts_trained
                                       + aware.rollouts_in_buffer
                                       + aware.rollouts_generating
                                       + aware.dropped)
    # …and the length-aware wall clock actually differs from the constant
    assert aware.wall_time_s != base.wall_time_s
    assert aware.steps == base.steps == 6
