"""Sharding rules + a miniature dry-run in a subprocess (8 fake devices).

The subprocess is required because jax locks the host device count at first
init — the main test process must keep seeing 1 device.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS, get_smoke_config
from repro.models.api import get_model

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_specs_cover_every_leaf(arch):
    """Every parameter gets a spec of matching rank; model-axis entries only
    on dims that exist."""
    from repro.parallel import sharding as shd
    from repro.launch.mesh import make_host_mesh
    cfg = get_smoke_config(arch)
    model = get_model(cfg)
    shapes = jax.eval_shape(lambda k: model.init(k, cfg),
                            jax.random.PRNGKey(0))
    mesh = make_host_mesh((1, 1), ("data", "model"))
    specs = shd.param_pspecs(shapes, cfg, mesh)
    leaves_s, _ = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))
    leaves_p = jax.tree_util.tree_leaves(shapes)
    assert len(leaves_s) == len(leaves_p)
    for spec, leaf in zip(leaves_s, leaves_p):
        assert isinstance(spec, P)
        assert len(spec) <= leaf.ndim, (spec, leaf.shape)


def test_zero_extend_picks_divisible_dim():
    from repro.parallel.sharding import zero_extend
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh((1, 1), ("data", "model"))
    # data axis size 1 → everything divides; largest unsharded dim chosen
    spec = zero_extend(P(None, "model"), (64, 128), mesh)
    assert spec[0] == ("data",) or spec[0] == "data" or spec == \
        P(("data",), "model") or spec == P("data", "model")


MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    from functools import partial
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_smoke_config
    from repro.models.api import get_model, train_input_specs
    from repro.optim.adamw import adamw_init
    from repro.parallel import sharding as shd
    from repro.rl.grpo import make_train_step
    from repro.launch.roofline import parse_collectives

    cfg = get_smoke_config("{arch}").replace(dtype="float32")
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    model = get_model(cfg)
    params_shape = jax.eval_shape(lambda k: model.init(k, cfg),
                                  jax.random.PRNGKey(0))
    p_sh = shd.named(shd.param_pspecs(params_shape, cfg, mesh), mesh)
    params_sds = jax.tree_util.tree_map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sp),
        params_shape, p_sh)
    opt_shape = jax.eval_shape(partial(adamw_init), params_shape)
    o_specs = dict(m=shd.opt_state_pspecs(params_shape, cfg, mesh),
                   v=shd.opt_state_pspecs(params_shape, cfg, mesh),
                   count=P())
    o_sh = shd.named(o_specs, mesh)
    opt_sds = jax.tree_util.tree_map(
        lambda s, sp: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sp),
        opt_shape, o_sh)
    bs = train_input_specs(cfg, batch=4, seq_len=32)
    bsp = shd.batch_pspecs(bs, mesh)
    batch_sds = {{k: jax.ShapeDtypeStruct(
        v.shape, v.dtype, sharding=NamedSharding(mesh, bsp[k]))
        for k, v in bs.items()}}
    with mesh:
        step = make_train_step(cfg)
        lowered = jax.jit(step, donate_argnums=(0, 1),
                          out_shardings=(p_sh, o_sh, None)).lower(
            params_sds, opt_sds, batch_sds)
        compiled = lowered.compile()
    stats = parse_collectives(compiled.as_text())
    ca = compiled.cost_analysis() or dict()
    if isinstance(ca, (list, tuple)):      # jax 0.4.x returns [dict]
        ca = ca[0] if ca else dict()
    print(json.dumps(dict(ok=True,
                          collectives=sum(stats.counts.values()),
                          flops=float(ca.get("flops", 0)))))
""")


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "qwen3-moe-235b-a22b",
                                  "xlstm-1.3b", "hymba-1.5b",
                                  "whisper-small"])
def test_mini_dryrun_compiles_and_has_collectives(arch):
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c",
                          MINI_DRYRUN.format(arch=arch)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"]
    assert res["collectives"] > 0        # TP really sharded something
