"""Simulator, checkpointing, and packing tests."""
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # minimal envs: seeded-sampling shim
    from _prop import given, settings, st

from repro.core.cluster import paper_heterogeneous
from repro.core.cost_model import LengthDistribution
from repro.core.model_spec import PAPER_MODELS
from repro.core.scheduler import SchedulerConfig, schedule
from repro.sim import AsyncRLSimulator, SimConfig
from repro.sim.events import FailureInjection, StragglerInjection

SPEC = PAPER_MODELS["1.5B"]
P = LengthDistribution(mean_len=1024, prompt_len=128)


@pytest.fixture(scope="module")
def plan():
    return schedule(SPEC, paper_heterogeneous(8, 8), P,
                    SchedulerConfig(tokens_per_step=2**18, stable_iters=3,
                                    max_iters=12))


def test_simulator_completes_and_conserves(plan):
    cfg = SimConfig(n_steps=10, rollouts_per_step=32, eta=4,
                    reward_cost_s=0.1)
    res = AsyncRLSimulator(plan, P, cfg).run()
    assert res.steps == 10
    assert res.throughput_tps > 0
    # tokens consumed = steps × B × (mean prompt+output), within lognormal CI
    expect = 10 * 32 * (P.mean_len + P.prompt_len)
    assert 0.5 * expect < res.tokens_consumed < 2.0 * expect
    assert res.max_staleness <= cfg.eta


def test_simulator_straggler_hurts(plan):
    base = AsyncRLSimulator(plan, P, SimConfig(
        n_steps=8, rollouts_per_step=32, eta=4, reward_cost_s=0.1)).run()
    n_rep = len(AsyncRLSimulator(plan, P).replicas)
    stragglers = [StragglerInjection(i, factor=0.05)
                  for i in range(max(1, n_rep // 2))]
    slow = AsyncRLSimulator(plan, P, SimConfig(
        n_steps=8, rollouts_per_step=32, eta=4, reward_cost_s=0.1,
        stragglers=stragglers)).run()
    assert slow.wall_time_s > base.wall_time_s


def test_simulator_failure_recovery(plan):
    fails = [FailureInjection(0, t_fail=1.0, downtime=50.0)]
    res = AsyncRLSimulator(plan, P, SimConfig(
        n_steps=6, rollouts_per_step=32, eta=4, reward_cost_s=0.1,
        failures=fails)).run()
    assert res.steps == 6          # survives the fault


# ------------------------------------------------------------------ ckpt
def test_checkpoint_roundtrip_and_gc(tmp_path):
    import jax.numpy as jnp
    from repro.ckpt.checkpoint import (latest_step, restore_checkpoint,
                                       save_checkpoint)
    state = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
             "version": 7}
    for step in (1, 2, 3, 4):
        save_checkpoint(tmp_path, step, state, keep=2)
    assert latest_step(tmp_path) == 4
    # gc kept only 2
    kept = [p.name for p in tmp_path.iterdir()]
    assert sorted(kept) == ["step-00000003", "step-00000004"]
    step, got = restore_checkpoint(tmp_path)
    assert step == 4 and got["version"] == 7
    np.testing.assert_array_equal(got["params"]["w"],
                                  np.arange(12.0).reshape(3, 4))


def test_checkpoint_atomicity_no_partial(tmp_path):
    from repro.ckpt.checkpoint import latest_step, save_checkpoint
    save_checkpoint(tmp_path, 5, {"x": np.ones(3)})
    # a crashed tmp dir must not count as a checkpoint
    (tmp_path / "tmp-6-deadbeef").mkdir()
    assert latest_step(tmp_path) == 5


# --------------------------------------------------------------- packing
@given(st.lists(st.integers(1, 4096), min_size=1, max_size=64),
       st.integers(1, 8))
@settings(max_examples=100, deadline=None)
def test_greedy_pack_partition_property(lengths, workers):
    from repro.data.packing import greedy_pack, pack_stats
    asg = greedy_pack(lengths, workers)
    flat = sorted(i for grp in asg for i in grp)
    assert flat == list(range(len(lengths)))       # exact partition
    mx, imb = pack_stats(lengths, asg)
    # LPT bound: max load ≤ 4/3·OPT + ... ≤ mean + max item
    mean = sum(lengths) / workers
    assert mx <= mean + max(lengths) + 1e-9


def test_greedy_pack_balances_better_than_round_robin():
    from repro.data.packing import greedy_pack, pack_stats
    rng = np.random.default_rng(0)
    lengths = rng.lognormal(7, 1, 64).astype(int).tolist()
    greedy = greedy_pack(lengths, 8)
    rr = [[i for i in range(len(lengths)) if i % 8 == w] for w in range(8)]
    _, imb_g = pack_stats(lengths, greedy)
    _, imb_rr = pack_stats(lengths, rr)
    assert imb_g <= imb_rr + 1e-9
