"""Property-based tests of the bounded-staleness invariants (hypothesis)."""
try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:                      # minimal envs: seeded-sampling shim
    from _prop import given, settings, st

from repro.core.staleness import StalenessConfig, StalenessController


@given(
    eta=st.integers(0, 5),
    b=st.integers(1, 8),
    ops=st.lists(st.sampled_from(["launch", "train", "consume"]),
                 min_size=1, max_size=200),
)
@settings(max_examples=100, deadline=None)
def test_capacity_control_guarantees_bound(eta, b, ops):
    """THE invariant: under (η+1)·B capacity control with oldest-first
    consumption, no consumed rollout ever exceeds staleness η."""
    cfg = StalenessConfig(eta=eta, rollouts_per_step=b)
    ctl = StalenessController(cfg)
    pending = []       # (version) of generated-but-unconsumed rollouts
    for op in ops:
        if op == "launch":
            if ctl.can_launch():
                ctl.launch()
                pending.append(ctl.version)
        elif op == "train" and len(pending) >= b:
            batch = pending[:b]
            pending = pending[b:]
            ctl.consume(batch)          # raises if bound violated
            ctl.bump_version()
        elif op == "consume" and pending:
            ctl.consume([pending.pop(0)])
    assert ctl.max_staleness() <= eta


@given(eta=st.integers(0, 4), b=st.integers(1, 8))
@settings(max_examples=50, deadline=None)
def test_capacity_formula(eta, b):
    ctl = StalenessController(StalenessConfig(eta=eta, rollouts_per_step=b))
    assert ctl.capacity == (eta + 1) * b
    launched = 0
    while ctl.can_launch():
        ctl.launch()
        launched += 1
    assert launched == ctl.capacity


def test_over_stale_consumption_raises():
    ctl = StalenessController(StalenessConfig(eta=1, rollouts_per_step=4))
    ctl.launch(1)
    v0 = ctl.version
    ctl.bump_version()
    ctl.bump_version()          # lag now 2 > η=1
    try:
        ctl.consume([v0])
        assert False, "expected staleness violation"
    except RuntimeError:
        pass


def test_adaptive_delta_stops_when_stable():
    from repro.core.staleness import adaptive_delta
    calls = []

    def run_window(delta):
        calls.append(delta)
        return float(delta)     # per-step cost constant ⇒ immediate stop

    d = adaptive_delta(run_window, StalenessConfig(eta=4))
    assert d == 4               # δ0 = max(1, η)
    assert calls == [4, 8]      # probed once, found stable, stopped
